"""Flat-buffer layout and bucket partitioner (optim/flat.py + optim/buckets.py).

Multi-device behavior (bucketed == monolithic reduce, scatter round-trips,
train-step parity) runs in subprocesses — see test_core_multidevice.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.buckets import (
    DEFAULT_BUCKET_BYTES, bucketed_all_reduce, flat_adam_apply, make_buckets,
    resolve_bucket_bytes,
)
from repro.optim.flat import (
    flat_adam_update, flatten, make_layout, unflatten,
)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "emb": rng.normal(size=(37, 16)).astype(np.float32),
        "blocks": {
            "w": rng.normal(size=(3, 16, 16)).astype(np.float32),
            "b": jnp.asarray(rng.normal(size=(3, 16)), jnp.bfloat16),
        },
        "scalar": np.float32(2.5),
    }


def test_layout_roundtrip_mixed_dtypes_and_padding():
    tree = _tree()
    layout = make_layout(tree, align=512)
    assert layout.total % 512 == 0
    assert layout.total >= layout.unpadded
    buf = flatten(layout, tree)
    assert buf.shape == (layout.total,) and buf.dtype == jnp.float32
    back = unflatten(layout, buf)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=1e-2)
    # dtype override (flat fp32 optimizer state sharing the layout)
    back32 = unflatten(layout, buf, dtype=jnp.float32)
    for leaf in jax.tree.leaves(back32):
        assert leaf.dtype == jnp.float32


def test_layout_empty_tree():
    layout = make_layout({})
    assert layout.unpadded == 0
    buf = flatten(layout, {})
    assert buf.shape == (layout.total,)


def test_buckets_cover_exactly_at_param_boundaries():
    tree = _tree()
    layout = make_layout(tree, align=512)
    for bb in (64, 256, 1024, 4096, 1 << 30):
        buckets = make_buckets(layout, bucket_bytes=bb)
        # exact cover of [0, total)
        assert buckets.starts[0] == 0
        for i in range(1, buckets.num_buckets):
            assert buckets.starts[i] == buckets.starts[i - 1] + buckets.sizes[i - 1]
        assert buckets.total == layout.total
        # every interior boundary is a parameter boundary
        param_offsets = set(layout.offsets)
        for s in buckets.starts[1:]:
            assert s in param_offsets
    # giant target -> one bucket; tiny target -> one bucket per param
    assert make_buckets(layout, bucket_bytes=1 << 30).num_buckets == 1
    per_param = make_buckets(layout, bucket_bytes=1)
    assert per_param.num_buckets == len(layout.sizes)


def test_buckets_shard_padding():
    tree = _tree()
    layout = make_layout(tree, align=512)
    buckets = make_buckets(layout, bucket_bytes=1024, n_shards=8)
    for size, pad_to in zip(buckets.sizes, buckets.padded):
        assert pad_to % 8 == 0 and 0 <= pad_to - size < 8
    assert buckets.scattered_total == sum(buckets.padded)
    assert buckets.local_total * 8 == buckets.scattered_total


def test_buckets_validation():
    layout = make_layout(_tree())
    with pytest.raises(ValueError, match="bucket_bytes"):
        make_buckets(layout, bucket_bytes=0)
    with pytest.raises(ValueError, match="n_shards"):
        make_buckets(layout, n_shards=0)


def test_resolve_bucket_bytes_numeric_and_auto():
    assert resolve_bucket_bytes(4.0) == 4 << 20
    assert resolve_bucket_bytes(0.05) == int(0.05 * (1 << 20))
    # auto: roofline-derived, positive, clamped to [1, 64] MiB
    b8 = resolve_bucket_bytes("auto", group_size=8)
    assert (1 << 20) <= b8 <= (64 << 20)
    # bigger groups -> wire factor grows -> buckets no larger
    assert resolve_bucket_bytes("auto", group_size=256) <= b8


def test_resolve_bucket_bytes_auto_falls_back_without_roofline(monkeypatch):
    """When the roofline lacks interconnect numbers, 'auto' keeps the
    static ~4 MiB default."""
    from repro.roofline import analysis

    monkeypatch.setattr(analysis, "ICI_LATENCY_S", None)
    assert resolve_bucket_bytes("auto", group_size=8) == DEFAULT_BUCKET_BYTES


def test_optconfig_bucket_mb_auto_builds_train_step():
    """OptConfig(bucket_mb='auto') resolves through the train-step builder."""
    from repro.configs import get_smoke_config
    from repro.optim import OptConfig
    from repro.train.step import TrainSettings, build_train_step
    from repro.models.common import ShardRules

    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    cfg = get_smoke_config("smollm-360m")
    opt = OptConfig(kind="adam", lr=1e-3, bucket_mb="auto")
    rules = ShardRules.for_mesh(mesh, faithful=True)
    step = build_train_step(cfg, mesh, rules, opt, TrainSettings(faithful=True))
    assert step._flat_engine == "faithful"
    assert step._flat_buckets.bucket_bytes == resolve_bucket_bytes(
        "auto", group_size=1)
    with pytest.raises(ValueError):
        OptConfig(bucket_mb="bogus")
    with pytest.raises(ValueError):
        OptConfig(bucket_mb=-1.0)


def test_bucketed_all_reduce_single_axis_identity():
    """On a 1-device axis the bucketed reduce is exact slicing+concat."""
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import PartitionSpec as P
    from repro import compat

    layout = make_layout(_tree())
    buckets = make_buckets(layout, bucket_bytes=512)
    buf = jnp.asarray(np.random.default_rng(2).normal(size=(layout.total,)),
                      jnp.float32)

    fn = jax.jit(compat.shard_map(
        lambda b: bucketed_all_reduce(b, buckets, "data"),
        mesh=mesh, in_specs=P(), out_specs=P(), check_vma=False,
    ))
    np.testing.assert_array_equal(np.asarray(fn(buf)), np.asarray(buf))


@pytest.mark.parametrize("n", [512, 1024 + 512])
def test_flat_adam_apply_kernel_matches_reference(n):
    rng = np.random.default_rng(n)
    p = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    m = jnp.asarray(np.abs(rng.normal(size=(n,))) * 0.1, jnp.float32)
    v = jnp.asarray(np.abs(rng.normal(size=(n,))) * 0.1, jnp.float32)
    step = jnp.int32(4)
    kw = dict(lr=1e-3, beta1=0.9, beta2=0.95, eps=1e-8, weight_decay=0.01)
    pk, mk, vk = flat_adam_apply(p, g, m, v, step, use_kernel=True, **kw)
    pr, mr, vr = flat_adam_apply(p, g, m, v, step, use_kernel=False, **kw)
    np.testing.assert_allclose(np.asarray(pk), np.asarray(pr), atol=1e-6)
    np.testing.assert_allclose(np.asarray(mk), np.asarray(mr), atol=1e-6)
    np.testing.assert_allclose(np.asarray(vk), np.asarray(vr), atol=1e-6)
    # reference path == the documented flat_adam_update math
    p2, m2, v2 = flat_adam_update(p, g, m, v, step, lr=1e-3)
    np.testing.assert_allclose(np.asarray(mr), np.asarray(m2), atol=0)
    np.testing.assert_allclose(np.asarray(vr), np.asarray(v2), atol=0)
