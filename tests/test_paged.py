"""Paged KV-cache subsystem: block-allocator property tests (hypothesis /
the _minihypothesis stand-in), block-table compaction invariants, paged
gather/scatter plumbing, the Pallas paged-decode kernel vs its oracle,
and THE layout-parity property — the paged engine (whole-bucket and
chunked prefill) must match the slotted engine token-for-token under
greedy decoding on a staggered-arrival trace."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.models import registry
from repro.serve import BlockAllocator, EngineConfig, ServeEngine, SlotTables, blocks_for
from repro.serve.paged import NULL_BLOCK


@pytest.fixture(scope="module")
def setup():
    from repro.launch.mesh import single_device_mesh
    from repro.models.common import ShardRules

    mesh = single_device_mesh()
    rules = ShardRules.for_mesh(mesh)
    # f32 so greedy comparisons against the slotted engine are exact
    cfg = dataclasses.replace(
        get_smoke_config("smollm-360m"), compute_dtype="float32")
    params = registry.get_module(cfg).init(cfg, jax.random.PRNGKey(0))
    return cfg, mesh, rules, params


def _prompts(cfg, rng, lens):
    return [rng.integers(0, cfg.vocab, n).astype(np.int32) for n in lens]


# ---------------------------------------------------------------------------
# Block allocator: property tests
# ---------------------------------------------------------------------------


@settings(max_examples=25)
@given(ops=st.lists(st.integers(min_value=0, max_value=9), min_size=0,
                    max_size=60))
def test_allocator_roundtrip_invariants(ops):
    """Random alloc/free walks: ids stay unique, the null block is never
    handed out, free+in_use always partitions the pool, and freed blocks
    become allocatable again."""
    alloc = BlockAllocator(num_blocks=8, block_size=4)
    held: list[int] = []
    for op in ops:
        if op < 6 and alloc.num_free:          # bias towards allocating
            b = alloc.alloc()
            assert b != NULL_BLOCK
            assert b not in held               # no double assignment
            held.append(b)
        elif held:
            alloc.free(held.pop(0))
        alloc.check()
        assert alloc.in_use == len(held)
    for b in held:
        alloc.free(b)
    alloc.check()
    assert alloc.num_free == alloc.capacity
    assert alloc.peak_in_use <= alloc.capacity


@settings(max_examples=25)
@given(ops=st.lists(st.integers(min_value=0, max_value=99), min_size=0,
                    max_size=100))
def test_refcount_allocator_interleavings(ops):
    """Arbitrary interleavings of alloc / share / free / preempt (bulk
    free) / publish / lookup: no double-free, no leak, no block freed
    while references live, and prefix-index lookups never return a block
    that sits on the free list."""
    alloc = BlockAllocator(num_blocks=9, block_size=4)
    refs: dict[int, int] = {}                  # shadow refcounts
    published: dict[int, bytes] = {}
    next_key = [0]
    for op in ops:
        kind = op % 6
        if kind in (0, 1):                      # alloc (bias)
            if alloc.available:
                b = alloc.alloc()
                assert refs.get(b, 0) == 0, "double assignment"
                refs[b] = 1
                published.pop(b, None)          # reclaimed cached block
        elif kind == 2 and refs:                # share a live block
            b = sorted(refs)[op % len(refs)]
            alloc.share(b)
            refs[b] += 1
        elif kind == 3 and refs:                # free one reference
            b = sorted(refs)[op % len(refs)]
            alloc.free(b)
            refs[b] -= 1
            if not refs[b]:
                del refs[b]
        elif kind == 4 and refs:                # publish a live block
            b = sorted(refs)[op % len(refs)]
            key = next_key[0].to_bytes(4, "big")
            next_key[0] += 1
            if alloc.publish(b, key):
                published[b] = key
        elif kind == 5 and refs:                # preempt: bulk release
            for b in list(refs):
                for _ in range(refs[b]):
                    alloc.free(b)
            refs.clear()
        alloc.check()
        assert alloc.in_use == len(refs)
        for b, n in refs.items():
            assert alloc.refcount(b) == n
        # a published key either resolves to a live/cached block or was
        # evicted — never to a block on the free list
        for b, key in list(published.items()):
            got = alloc.lookup([key])
            if not got:
                del published[b]                # evicted or superseded
                continue
            assert got == [b]
            assert alloc.refcount(b) >= 1 or alloc.num_cached > 0
    for b in list(refs):
        for _ in range(refs.pop(b)):
            alloc.free(b)
    alloc.check()
    assert alloc.in_use == 0
    assert alloc.num_free + alloc.num_cached == alloc.capacity


def test_refcount_share_and_cached_lifecycle():
    """share() stacks references; free() only releases at refcount 0;
    published blocks park in the cached set instead of the free list and
    revive on the next hit; the LRU cached block is reclaimed when the
    free list runs dry."""
    alloc = BlockAllocator(num_blocks=4, block_size=2)
    a = alloc.alloc()
    alloc.share(a)
    alloc.free(a)
    assert alloc.refcount(a) == 1 and alloc.in_use == 1   # still live
    with pytest.raises(ValueError):
        alloc.share(99)
    assert alloc.publish(a, b"ka")
    assert not alloc.publish(a, b"kb")                    # one key per block
    alloc.free(a)
    assert alloc.in_use == 0 and alloc.num_cached == 1    # parked, not freed
    assert alloc.lookup([b"ka"]) == [a]
    alloc.share(a)                                        # revive from cache
    assert alloc.refcount(a) == 1 and alloc.num_cached == 0
    alloc.free(a)

    # exhaust the free list: the LRU cached block gets reclaimed and its
    # index entry dropped
    b = alloc.alloc()
    c = alloc.alloc()
    assert {b, c} == {2, 3}    # cached block a skipped while free ids remain
    d = alloc.alloc()          # free list empty -> evicts cached block a
    assert d == a
    assert alloc.lookup([b"ka"]) == []
    assert alloc.cache_evictions == 1
    alloc.check()


def test_prefix_keys_chain():
    from repro.serve import prefix_keys

    t = np.arange(20, dtype=np.int32)
    keys = prefix_keys(t, 8)
    assert len(keys) == 2                       # only full blocks
    # chain keys commit to the whole history, not just the block's tokens
    t2 = t.copy()
    t2[0] = 99
    keys2 = prefix_keys(t2, 8)
    assert keys[0] != keys2[0] and keys[1] != keys2[1]
    # equal prefixes share keys
    assert prefix_keys(t[:16], 8) == keys
    assert prefix_keys(t, 8)[0] == keys[0]
    assert prefix_keys(np.asarray([], np.int32), 8) == []


def test_allocator_exhaustion_and_errors():
    alloc = BlockAllocator(num_blocks=4, block_size=2)
    got = [alloc.alloc() for _ in range(3)]
    assert sorted(got) == [1, 2, 3]
    with pytest.raises(RuntimeError):
        alloc.alloc()
    with pytest.raises(ValueError):
        alloc.free(NULL_BLOCK)
    with pytest.raises(ValueError):
        alloc.free(99)
    alloc.free(got[1])
    assert alloc.alloc() == got[1]              # lowest-id-first reuse
    with pytest.raises(ValueError):
        BlockAllocator(num_blocks=1, block_size=2)
    with pytest.raises(ValueError):
        BlockAllocator(num_blocks=4, block_size=0)


@settings(max_examples=25)
@given(ops=st.lists(st.integers(min_value=0, max_value=11), min_size=0,
                    max_size=60))
def test_slot_tables_compaction_invariants(ops):
    """Random append/release walks over slots: every table row stays a
    contiguous prefix of live blocks (the compaction invariant), no block
    is mapped by two slots, and release returns exactly what was mapped."""
    alloc = BlockAllocator(num_blocks=10, block_size=4)
    tables = SlotTables(max_slots=3, blocks_per_slot=3)
    for op in ops:
        slot = op % 3
        if op < 9:                             # bias towards appending
            if alloc.num_free and tables.mapped(slot) < tables.blocks_per_slot:
                tables.append(slot, alloc.alloc())
        else:
            before = tables.blocks(slot)
            freed = tables.release(slot)
            assert tuple(freed) == before
            for b in freed:
                alloc.free(b)
        tables.check()
        alloc.check()
        total_mapped = sum(tables.mapped(s) for s in range(3))
        assert total_mapped == alloc.in_use
    tables.check()


def test_slot_tables_errors():
    tables = SlotTables(max_slots=2, blocks_per_slot=2)
    with pytest.raises(ValueError):
        tables.append(0, NULL_BLOCK)
    tables.append(0, 1)
    tables.append(0, 2)
    with pytest.raises(ValueError):
        tables.append(0, 3)                     # row full
    assert tables.release(1) == []


def test_blocks_for():
    assert blocks_for(0, 4) == 0
    assert blocks_for(1, 4) == 1
    assert blocks_for(4, 4) == 1
    assert blocks_for(5, 4) == 2


# ---------------------------------------------------------------------------
# Paged attention: plumbing + kernel vs oracle
# ---------------------------------------------------------------------------


def test_paged_write_gather_roundtrip():
    from repro.models.attention import (
        paged_gather, paged_write_positions, paged_write_token)

    rng = np.random.default_rng(0)
    NB, bs, Hk, D = 9, 4, 2, 8
    pool = jnp.asarray(rng.normal(size=(NB, bs, Hk, D)), jnp.float32)
    tables = jnp.asarray([[1, 2, 0], [4, 5, 6]], jnp.int32)

    # per-lane token write lands at the logical position
    lengths = jnp.asarray([5, 9], jnp.int32)
    new = jnp.asarray(rng.normal(size=(2, Hk, D)), jnp.float32)
    lanes = paged_gather(paged_write_token(pool, tables, lengths, new), tables)
    for b in range(2):
        np.testing.assert_array_equal(
            np.asarray(lanes[b, int(lengths[b])]), np.asarray(new[b]))

    # chunk write: valid positions land in order, invalid go to the sink
    pos = jnp.arange(4) + 2
    vals = jnp.asarray(rng.normal(size=(4, Hk, D)), jnp.float32)
    out = paged_write_positions(pool, tables[0], pos, vals, valid=pos < 5)
    lane = paged_gather(out, tables[0][None])[0]
    np.testing.assert_array_equal(np.asarray(lane[2:5]), np.asarray(vals[:3]))
    # the sink (block 0) rows never appear at mapped positions
    np.testing.assert_array_equal(
        np.asarray(lane[5]), np.asarray(pool[2, 1]))   # untouched


@pytest.mark.parametrize("window,softcap", [(0, 0.0), (4, 0.0), (0, 5.0)])
def test_paged_kernel_matches_ref(window, softcap):
    from repro.kernels.paged_attention.ops import paged_attention
    from repro.kernels.paged_attention.ref import paged_attention_ref

    rng = np.random.default_rng(1)
    B, Hk, rep, D, NB, bs, nb = 3, 2, 3, 16, 9, 4, 6
    q = jnp.asarray(rng.normal(size=(B, Hk, rep, D)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(NB, bs, Hk, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(NB, bs, Hk, D)), jnp.float32)
    lengths = jnp.asarray([5, 0, 13], jnp.int32)
    tables = jnp.asarray(
        [[1, 2, 0, 0, 0, 0], [3, 0, 0, 0, 0, 0], [4, 5, 6, 7, 0, 0]],
        jnp.int32)
    out = paged_attention(q, kp, vp, lengths, tables,
                          window=window, softcap=softcap, interpret=True)
    ref = paged_attention_ref(q, kp, vp, lengths, tables,
                              window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_paged_decode_attention_matches_slotted(setup):
    """The jnp reference path must equal the slotted ``decode_attention``
    bitwise on equal logical inputs — the anchor of engine-level parity."""
    from repro.models.attention import (
        DecodeSharding, decode_attention, paged_decode_attention,
        paged_write_positions)

    cfg, mesh, rules, params = setup
    rng = np.random.default_rng(2)
    B, Hk, rep, D = 2, 1, 3, 16
    S, bs = 16, 4
    lengths = jnp.asarray([5, 9], jnp.int32)
    tables = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], jnp.int32)
    kv = rng.normal(size=(2, B, S, Hk, D)).astype(np.float32)
    q = jnp.asarray(rng.normal(size=(B, Hk, rep, D)), jnp.float32)
    kn = jnp.asarray(rng.normal(size=(B, Hk, D)), jnp.float32)
    vn = jnp.asarray(rng.normal(size=(B, Hk, D)), jnp.float32)

    # slotted layout: (B, S, ...) lanes
    k_lane, v_lane = jnp.asarray(kv[0]), jnp.asarray(kv[1])
    dec = DecodeSharding.choose(mesh, B)
    want, _, _ = decode_attention(
        q, k_lane, v_lane, kn, vn, lengths, sharding=dec)

    # paged layout: same logical contents scattered through the tables
    pools = []
    for lane in kv:
        pool = jnp.zeros((9, bs, Hk, D), jnp.float32)
        for b in range(B):
            pool = paged_write_positions(
                pool, tables[b], jnp.arange(S), jnp.asarray(lane[b]))
        pools.append(pool)
    got, _, _ = paged_decode_attention(
        q, pools[0], pools[1], kn, vn, lengths, tables)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# Engine-level layout parity
# ---------------------------------------------------------------------------


def _staggered_tokens(cfg, mesh, rules, params, ec):
    rng = np.random.default_rng(3)
    lens = [5, 11, 8, 14, 4]
    budgets = [7, 3, 5, 2, 6]
    prompts = _prompts(cfg, rng, lens)
    eng = ServeEngine(cfg, mesh, rules, params, ec)
    rids = [eng.submit(p, max_new_tokens=b) for p, b in zip(prompts, budgets)]
    eng.drain()
    return [list(eng.completions[r].tokens) for r in rids], eng


def test_paged_matches_slotted_staggered(setup):
    """THE paged-correctness property: on a staggered trace (more requests
    than lanes, heterogeneous lengths, lanes reused), the paged engine —
    whole-bucket prefill — produces exactly the slotted engine's greedy
    tokens, while reserving strictly less KV HBM."""
    cfg, mesh, rules, params = setup
    want, slotted = _staggered_tokens(
        cfg, mesh, rules, params, EngineConfig(max_slots=2, max_len=32))
    got, paged = _staggered_tokens(
        cfg, mesh, rules, params,
        EngineConfig(max_slots=2, max_len=32, kv_layout="paged",
                     page_size=8, num_blocks=7))
    assert got == want
    assert paged.kv_reserved_bytes < slotted.kv_reserved_bytes
    assert paged.stats["kv_peak_used_bytes"] <= paged.kv_reserved_bytes
    # every block returned to the pool once the trace drained
    assert paged.alloc.in_use == 0
    paged.alloc.check()
    paged.tables.check()


def test_chunked_prefill_matches_slotted(setup):
    """Chunked prefill (prompts admitted 4 tokens per step, interleaved
    with decode) must still match the slotted engine's greedy tokens."""
    cfg, mesh, rules, params = setup
    want, _ = _staggered_tokens(
        cfg, mesh, rules, params, EngineConfig(max_slots=2, max_len=32))
    got, eng = _staggered_tokens(
        cfg, mesh, rules, params,
        EngineConfig(max_slots=2, max_len=32, kv_layout="paged",
                     page_size=8, num_blocks=7, prefill_chunk=4))
    assert got == want
    # chunking really happened: more chunk calls than prompts
    assert eng.counters["prefill_chunks"] > eng.counters["prefills"]


def test_paged_pallas_backend_matches(setup):
    cfg, mesh, rules, params = setup
    want, _ = _staggered_tokens(
        cfg, mesh, rules, params, EngineConfig(max_slots=2, max_len=32))
    got, _ = _staggered_tokens(
        cfg, mesh, rules, params,
        EngineConfig(max_slots=2, max_len=32, kv_layout="paged",
                     page_size=8, paged_attn="pallas"))
    assert got == want


def test_paged_block_budget_gates_admission(setup):
    """With a pool too small to hold two worst-case requests, the second
    waits in the queue until the first frees its blocks — and both still
    complete correctly."""
    cfg, mesh, rules, params = setup
    rng = np.random.default_rng(4)
    prompts = _prompts(cfg, rng, [8, 8])
    eng = ServeEngine(
        cfg, mesh, rules, params,
        EngineConfig(max_slots=2, max_len=16, kv_layout="paged",
                     page_size=4, num_blocks=5))   # 4 usable blocks
    rids = [eng.submit(p, max_new_tokens=6) for p in prompts]
    eng.step()
    # only one lane admitted: the other's worst case (4 blocks) can't be
    # covered alongside the first's commitment
    assert sum(s is not None for s in eng.slots) == 1
    assert len(eng.queue) == 1
    eng.drain()
    assert all(len(eng.completions[r].tokens) == 6 for r in rids)
    assert eng.alloc.in_use == 0

    # a single request whose worst case exceeds the whole pool can NEVER
    # be admitted: submit refuses it up front
    tiny = ServeEngine(
        cfg, mesh, rules, params,
        EngineConfig(max_slots=2, max_len=16, kv_layout="paged",
                     page_size=4, num_blocks=4),   # 3 usable blocks
        aot=eng.aot,
    )
    with pytest.raises(ValueError):
        tiny.submit(np.arange(8), max_new_tokens=6)   # needs 4 blocks


def test_paged_engine_steady_builds_flat(setup):
    """Steady state on the paged path may not build executables — chunked
    prefill must not reintroduce per-length compiles."""
    cfg, mesh, rules, params = setup
    rng = np.random.default_rng(5)
    eng = ServeEngine(
        cfg, mesh, rules, params,
        EngineConfig(max_slots=2, max_len=32, kv_layout="paged",
                     page_size=8, prefill_chunk=4))
    eng.run(_prompts(cfg, rng, [3, 9, 14]), max_new_tokens=3)
    builds = eng.stats["builds"]
    # decode + first-chunk + continuation-chunk executables, nothing else
    assert builds == 3
    eng.run(_prompts(cfg, rng, [5, 13, 7, 2]), max_new_tokens=4)
    assert eng.stats["builds"] == builds


def test_paged_engine_validation(setup):
    cfg, mesh, rules, params = setup
    with pytest.raises(ValueError):
        ServeEngine(cfg, mesh, rules, params,
                    EngineConfig(kv_layout="bogus"))
    with pytest.raises(ValueError):
        ServeEngine(cfg, mesh, rules, params,
                    EngineConfig(kv_layout="slotted", prefill_chunk=8))
    with pytest.raises(ValueError):   # max_len not a multiple of page_size
        ServeEngine(cfg, mesh, rules, params,
                    EngineConfig(max_len=30, kv_layout="paged", page_size=8))
    with pytest.raises(ValueError):   # prefix caching needs block tables
        ServeEngine(cfg, mesh, rules, params,
                    EngineConfig(kv_layout="slotted", prefix_cache=True))
    with pytest.raises(ValueError):   # so does preemption
        ServeEngine(cfg, mesh, rules, params,
                    EngineConfig(kv_layout="slotted", admission="preempt"))
    with pytest.raises(ValueError):
        ServeEngine(cfg, mesh, rules, params,
                    EngineConfig(kv_layout="paged", admission="bogus"))


# ---------------------------------------------------------------------------
# Prefix caching + preemption (engine level)
# ---------------------------------------------------------------------------


def test_prefix_cache_skips_shared_prefill(setup):
    """Two requests sharing a 16-token system prompt: the second admission
    matches the published block chain, prefills only its suffix, and still
    emits exactly the no-cache engine's greedy tokens."""
    cfg, mesh, rules, params = setup
    rng = np.random.default_rng(10)
    sysp = rng.integers(0, cfg.vocab, 16).astype(np.int32)
    prompts = [np.concatenate([sysp, rng.integers(0, cfg.vocab, n)
                               .astype(np.int32)]) for n in (5, 7)]

    def run(prefix):
        eng = ServeEngine(
            cfg, mesh, rules, params,
            EngineConfig(max_slots=1, max_len=32, kv_layout="paged",
                         page_size=8, prefix_cache=prefix))
        out = eng.run(prompts, max_new_tokens=4)
        return [t.tolist() for t in out], eng

    want, plain = run(prefix=False)
    got, cached = run(prefix=True)
    assert got == want
    # max_slots=1 serializes the two requests, so the second's 16 shared
    # positions come from the cache: exactly 16 fewer tokens prefilled
    assert cached.counters["prefix_hit_tokens"] == 16
    assert cached.counters["prefill_tokens"] \
        == plain.counters["prefill_tokens"] - 16
    assert cached.stats["prefix_hit_rate"] > 0.3
    # drained: every block is free or parked in the prefix cache
    assert cached.alloc.in_use == 0
    assert cached.alloc.num_cached > 0
    cached.check_invariants()


def test_prefix_cache_cow_tail(setup):
    """A prompt that is EXACTLY a published block chain (plen % bs == 0)
    must copy-on-write the tail block — the sampling position is
    recomputed in a private copy, never written into the shared block —
    and match the no-cache stream."""
    cfg, mesh, rules, params = setup
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab, 16).astype(np.int32)  # 2 full blocks

    def run(prefix):
        eng = ServeEngine(
            cfg, mesh, rules, params,
            EngineConfig(max_slots=1, max_len=32, kv_layout="paged",
                         page_size=8, prefix_cache=prefix))
        out = eng.run([prompt, prompt.copy()], max_new_tokens=4)
        return [t.tolist() for t in out], eng

    want, _ = run(prefix=False)
    got, eng = run(prefix=True)
    assert got == want
    assert got[0] == got[1]                     # identical prompts agree
    assert eng.counters["cow_copies"] == 1
    # COW recomputes exactly one position: the 15 before it are hits
    assert eng.counters["prefix_hit_tokens"] == 15
    eng.check_invariants()


def test_preempt_requeue_completes_with_parity(setup):
    """A pool too small for every lane's worst case under
    admission='preempt': lanes are admitted on immediate need, decode
    growth preempts the lowest-priority lane back to the queue, and every
    request still finishes with the slotted engine's exact tokens."""
    cfg, mesh, rules, params = setup
    rng = np.random.default_rng(12)
    prompts = _prompts(cfg, rng, [9, 12, 7])
    budgets = [8, 6, 7]

    def run(ec):
        eng = ServeEngine(cfg, mesh, rules, params, ec)
        rids = [eng.submit(p, max_new_tokens=b)
                for p, b in zip(prompts, budgets)]
        while eng.has_work():
            eng.step()
            eng.check_invariants()
        return [list(eng.completions[r].tokens) for r in rids], eng

    want, _ = run(EngineConfig(max_slots=3, max_len=32))
    got, eng = run(EngineConfig(
        max_slots=3, max_len=32, kv_layout="paged", page_size=8,
        num_blocks=7, admission="preempt"))       # 6 usable blocks for 3 lanes
    assert got == want
    assert eng.counters["preemptions"] > 0
    assert eng.counters["resumed"] == eng.counters["preemptions"]
    assert eng.counters["admitted"] == eng.counters["evicted"] == len(prompts)
    assert eng.alloc.in_use == 0


def test_preempt_stochastic_resume_is_coherent(setup):
    """Preempting a temperature>0 lane must not fork its stream: during
    the resume replay the engine forces the RECORDED tokens as decode
    inputs (a re-sample at a different key-stream position would diverge
    from the emitted history and poison the prefix index).  Completions
    keep exactly their budget, runs are seed-deterministic, and the
    replay machinery demonstrably fired."""
    cfg, mesh, rules, params = setup
    rng = np.random.default_rng(15)
    prompts = _prompts(cfg, rng, [9, 12, 7])
    budgets = [8, 6, 7]

    def run():
        eng = ServeEngine(cfg, mesh, rules, params, EngineConfig(
            max_slots=3, max_len=32, kv_layout="paged", page_size=8,
            num_blocks=7, admission="preempt", seed=11))
        rids = [eng.submit(p, max_new_tokens=b, temperature=1.5)
                for p, b in zip(prompts, budgets)]
        while eng.has_work():
            eng.step()
            eng.check_invariants()
        return [list(eng.completions[r].tokens) for r in rids], eng

    a, eng = run()
    assert eng.counters["preemptions"] > 0
    assert eng.counters["replayed_tokens"] > 0
    for tokens, b in zip(a, budgets):
        assert len(tokens) == b
    b_, _ = run()
    assert a == b_                               # seed-deterministic


def test_preempt_single_lane_never_livelocks(setup):
    """A single request whose worst case fits the pool exactly must run
    to completion alone — preemption never evicts the only lane into an
    infinite requeue loop."""
    cfg, mesh, rules, params = setup
    rng = np.random.default_rng(13)
    prompt = _prompts(cfg, rng, [9])[0]
    eng = ServeEngine(
        cfg, mesh, rules, params,
        EngineConfig(max_slots=2, max_len=16, kv_layout="paged",
                     page_size=4, num_blocks=5, admission="preempt"))
    rid = eng.submit(prompt, max_new_tokens=8)    # needs all 4 usable blocks
    for _ in range(200):
        if not eng.step():
            break
        eng.check_invariants()
    assert len(eng.completions[rid].tokens) == 8


def test_prebuild_covers_prefix_and_preempt_dispatch(setup):
    """After ``prebuild()``, no schedule — prefix hits, misses, COW,
    preemption resumes — may compile another executable (the builds-flat
    guarantee CI leans on)."""
    cfg, mesh, rules, params = setup
    rng = np.random.default_rng(14)
    eng = ServeEngine(
        cfg, mesh, rules, params,
        EngineConfig(max_slots=2, max_len=32, kv_layout="paged",
                     page_size=8, num_blocks=9, prefix_cache=True,
                     admission="preempt"))
    eng.prebuild()
    builds = eng.stats["builds"]
    sysp = rng.integers(0, cfg.vocab, 16).astype(np.int32)
    prompts = [np.concatenate([sysp, t]) for t in _prompts(cfg, rng, [4, 6])]
    prompts += [sysp.copy()] + _prompts(cfg, rng, [11, 3])
    eng.run(prompts, max_new_tokens=6)
    assert eng.counters["prefix_hit_tokens"] > 0
    assert eng.stats["builds"] == builds
