"""Paged KV-cache subsystem: block-allocator property tests (hypothesis /
the _minihypothesis stand-in), block-table compaction invariants, paged
gather/scatter plumbing, the Pallas paged-decode kernel vs its oracle,
and THE layout-parity property — the paged engine (whole-bucket and
chunked prefill) must match the slotted engine token-for-token under
greedy decoding on a staggered-arrival trace."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.models import registry
from repro.serve import BlockAllocator, EngineConfig, ServeEngine, SlotTables, blocks_for
from repro.serve.paged import NULL_BLOCK


@pytest.fixture(scope="module")
def setup():
    from repro.launch.mesh import single_device_mesh
    from repro.models.common import ShardRules

    mesh = single_device_mesh()
    rules = ShardRules.for_mesh(mesh)
    # f32 so greedy comparisons against the slotted engine are exact
    cfg = dataclasses.replace(
        get_smoke_config("smollm-360m"), compute_dtype="float32")
    params = registry.get_module(cfg).init(cfg, jax.random.PRNGKey(0))
    return cfg, mesh, rules, params


def _prompts(cfg, rng, lens):
    return [rng.integers(0, cfg.vocab, n).astype(np.int32) for n in lens]


# ---------------------------------------------------------------------------
# Block allocator: property tests
# ---------------------------------------------------------------------------


@settings(max_examples=25)
@given(ops=st.lists(st.integers(min_value=0, max_value=9), min_size=0,
                    max_size=60))
def test_allocator_roundtrip_invariants(ops):
    """Random alloc/free walks: ids stay unique, the null block is never
    handed out, free+in_use always partitions the pool, and freed blocks
    become allocatable again."""
    alloc = BlockAllocator(num_blocks=8, block_size=4)
    held: list[int] = []
    for op in ops:
        if op < 6 and alloc.num_free:          # bias towards allocating
            b = alloc.alloc()
            assert b != NULL_BLOCK
            assert b not in held               # no double assignment
            held.append(b)
        elif held:
            alloc.free(held.pop(0))
        alloc.check()
        assert alloc.in_use == len(held)
    for b in held:
        alloc.free(b)
    alloc.check()
    assert alloc.num_free == alloc.capacity
    assert alloc.peak_in_use <= alloc.capacity


def test_allocator_exhaustion_and_errors():
    alloc = BlockAllocator(num_blocks=4, block_size=2)
    got = [alloc.alloc() for _ in range(3)]
    assert sorted(got) == [1, 2, 3]
    with pytest.raises(RuntimeError):
        alloc.alloc()
    with pytest.raises(ValueError):
        alloc.free(NULL_BLOCK)
    with pytest.raises(ValueError):
        alloc.free(99)
    alloc.free(got[1])
    assert alloc.alloc() == got[1]              # lowest-id-first reuse
    with pytest.raises(ValueError):
        BlockAllocator(num_blocks=1, block_size=2)
    with pytest.raises(ValueError):
        BlockAllocator(num_blocks=4, block_size=0)


@settings(max_examples=25)
@given(ops=st.lists(st.integers(min_value=0, max_value=11), min_size=0,
                    max_size=60))
def test_slot_tables_compaction_invariants(ops):
    """Random append/release walks over slots: every table row stays a
    contiguous prefix of live blocks (the compaction invariant), no block
    is mapped by two slots, and release returns exactly what was mapped."""
    alloc = BlockAllocator(num_blocks=10, block_size=4)
    tables = SlotTables(max_slots=3, blocks_per_slot=3)
    for op in ops:
        slot = op % 3
        if op < 9:                             # bias towards appending
            if alloc.num_free and tables.mapped(slot) < tables.blocks_per_slot:
                tables.append(slot, alloc.alloc())
        else:
            before = tables.blocks(slot)
            freed = tables.release(slot)
            assert tuple(freed) == before
            for b in freed:
                alloc.free(b)
        tables.check()
        alloc.check()
        total_mapped = sum(tables.mapped(s) for s in range(3))
        assert total_mapped == alloc.in_use
    tables.check()


def test_slot_tables_errors():
    tables = SlotTables(max_slots=2, blocks_per_slot=2)
    with pytest.raises(ValueError):
        tables.append(0, NULL_BLOCK)
    tables.append(0, 1)
    tables.append(0, 2)
    with pytest.raises(ValueError):
        tables.append(0, 3)                     # row full
    assert tables.release(1) == []


def test_blocks_for():
    assert blocks_for(0, 4) == 0
    assert blocks_for(1, 4) == 1
    assert blocks_for(4, 4) == 1
    assert blocks_for(5, 4) == 2


# ---------------------------------------------------------------------------
# Paged attention: plumbing + kernel vs oracle
# ---------------------------------------------------------------------------


def test_paged_write_gather_roundtrip():
    from repro.models.attention import (
        paged_gather, paged_write_positions, paged_write_token)

    rng = np.random.default_rng(0)
    NB, bs, Hk, D = 9, 4, 2, 8
    pool = jnp.asarray(rng.normal(size=(NB, bs, Hk, D)), jnp.float32)
    tables = jnp.asarray([[1, 2, 0], [4, 5, 6]], jnp.int32)

    # per-lane token write lands at the logical position
    lengths = jnp.asarray([5, 9], jnp.int32)
    new = jnp.asarray(rng.normal(size=(2, Hk, D)), jnp.float32)
    lanes = paged_gather(paged_write_token(pool, tables, lengths, new), tables)
    for b in range(2):
        np.testing.assert_array_equal(
            np.asarray(lanes[b, int(lengths[b])]), np.asarray(new[b]))

    # chunk write: valid positions land in order, invalid go to the sink
    pos = jnp.arange(4) + 2
    vals = jnp.asarray(rng.normal(size=(4, Hk, D)), jnp.float32)
    out = paged_write_positions(pool, tables[0], pos, vals, valid=pos < 5)
    lane = paged_gather(out, tables[0][None])[0]
    np.testing.assert_array_equal(np.asarray(lane[2:5]), np.asarray(vals[:3]))
    # the sink (block 0) rows never appear at mapped positions
    np.testing.assert_array_equal(
        np.asarray(lane[5]), np.asarray(pool[2, 1]))   # untouched


@pytest.mark.parametrize("window,softcap", [(0, 0.0), (4, 0.0), (0, 5.0)])
def test_paged_kernel_matches_ref(window, softcap):
    from repro.kernels.paged_attention.ops import paged_attention
    from repro.kernels.paged_attention.ref import paged_attention_ref

    rng = np.random.default_rng(1)
    B, Hk, rep, D, NB, bs, nb = 3, 2, 3, 16, 9, 4, 6
    q = jnp.asarray(rng.normal(size=(B, Hk, rep, D)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(NB, bs, Hk, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(NB, bs, Hk, D)), jnp.float32)
    lengths = jnp.asarray([5, 0, 13], jnp.int32)
    tables = jnp.asarray(
        [[1, 2, 0, 0, 0, 0], [3, 0, 0, 0, 0, 0], [4, 5, 6, 7, 0, 0]],
        jnp.int32)
    out = paged_attention(q, kp, vp, lengths, tables,
                          window=window, softcap=softcap, interpret=True)
    ref = paged_attention_ref(q, kp, vp, lengths, tables,
                              window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_paged_decode_attention_matches_slotted(setup):
    """The jnp reference path must equal the slotted ``decode_attention``
    bitwise on equal logical inputs — the anchor of engine-level parity."""
    from repro.models.attention import (
        DecodeSharding, decode_attention, paged_decode_attention,
        paged_write_positions)

    cfg, mesh, rules, params = setup
    rng = np.random.default_rng(2)
    B, Hk, rep, D = 2, 1, 3, 16
    S, bs = 16, 4
    lengths = jnp.asarray([5, 9], jnp.int32)
    tables = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], jnp.int32)
    kv = rng.normal(size=(2, B, S, Hk, D)).astype(np.float32)
    q = jnp.asarray(rng.normal(size=(B, Hk, rep, D)), jnp.float32)
    kn = jnp.asarray(rng.normal(size=(B, Hk, D)), jnp.float32)
    vn = jnp.asarray(rng.normal(size=(B, Hk, D)), jnp.float32)

    # slotted layout: (B, S, ...) lanes
    k_lane, v_lane = jnp.asarray(kv[0]), jnp.asarray(kv[1])
    dec = DecodeSharding.choose(mesh, B)
    want, _, _ = decode_attention(
        q, k_lane, v_lane, kn, vn, lengths, sharding=dec)

    # paged layout: same logical contents scattered through the tables
    pools = []
    for lane in kv:
        pool = jnp.zeros((9, bs, Hk, D), jnp.float32)
        for b in range(B):
            pool = paged_write_positions(
                pool, tables[b], jnp.arange(S), jnp.asarray(lane[b]))
        pools.append(pool)
    got, _, _ = paged_decode_attention(
        q, pools[0], pools[1], kn, vn, lengths, tables)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# Engine-level layout parity
# ---------------------------------------------------------------------------


def _staggered_tokens(cfg, mesh, rules, params, ec):
    rng = np.random.default_rng(3)
    lens = [5, 11, 8, 14, 4]
    budgets = [7, 3, 5, 2, 6]
    prompts = _prompts(cfg, rng, lens)
    eng = ServeEngine(cfg, mesh, rules, params, ec)
    rids = [eng.submit(p, max_new_tokens=b) for p, b in zip(prompts, budgets)]
    eng.drain()
    return [list(eng.completions[r].tokens) for r in rids], eng


def test_paged_matches_slotted_staggered(setup):
    """THE paged-correctness property: on a staggered trace (more requests
    than lanes, heterogeneous lengths, lanes reused), the paged engine —
    whole-bucket prefill — produces exactly the slotted engine's greedy
    tokens, while reserving strictly less KV HBM."""
    cfg, mesh, rules, params = setup
    want, slotted = _staggered_tokens(
        cfg, mesh, rules, params, EngineConfig(max_slots=2, max_len=32))
    got, paged = _staggered_tokens(
        cfg, mesh, rules, params,
        EngineConfig(max_slots=2, max_len=32, kv_layout="paged",
                     page_size=8, num_blocks=7))
    assert got == want
    assert paged.kv_reserved_bytes < slotted.kv_reserved_bytes
    assert paged.stats["kv_peak_used_bytes"] <= paged.kv_reserved_bytes
    # every block returned to the pool once the trace drained
    assert paged.alloc.in_use == 0
    paged.alloc.check()
    paged.tables.check()


def test_chunked_prefill_matches_slotted(setup):
    """Chunked prefill (prompts admitted 4 tokens per step, interleaved
    with decode) must still match the slotted engine's greedy tokens."""
    cfg, mesh, rules, params = setup
    want, _ = _staggered_tokens(
        cfg, mesh, rules, params, EngineConfig(max_slots=2, max_len=32))
    got, eng = _staggered_tokens(
        cfg, mesh, rules, params,
        EngineConfig(max_slots=2, max_len=32, kv_layout="paged",
                     page_size=8, num_blocks=7, prefill_chunk=4))
    assert got == want
    # chunking really happened: more chunk calls than prompts
    assert eng.counters["prefill_chunks"] > eng.counters["prefills"]


def test_paged_pallas_backend_matches(setup):
    cfg, mesh, rules, params = setup
    want, _ = _staggered_tokens(
        cfg, mesh, rules, params, EngineConfig(max_slots=2, max_len=32))
    got, _ = _staggered_tokens(
        cfg, mesh, rules, params,
        EngineConfig(max_slots=2, max_len=32, kv_layout="paged",
                     page_size=8, paged_attn="pallas"))
    assert got == want


def test_paged_block_budget_gates_admission(setup):
    """With a pool too small to hold two worst-case requests, the second
    waits in the queue until the first frees its blocks — and both still
    complete correctly."""
    cfg, mesh, rules, params = setup
    rng = np.random.default_rng(4)
    prompts = _prompts(cfg, rng, [8, 8])
    eng = ServeEngine(
        cfg, mesh, rules, params,
        EngineConfig(max_slots=2, max_len=16, kv_layout="paged",
                     page_size=4, num_blocks=5))   # 4 usable blocks
    rids = [eng.submit(p, max_new_tokens=6) for p in prompts]
    eng.step()
    # only one lane admitted: the other's worst case (4 blocks) can't be
    # covered alongside the first's commitment
    assert sum(s is not None for s in eng.slots) == 1
    assert len(eng.queue) == 1
    eng.drain()
    assert all(len(eng.completions[r].tokens) == 6 for r in rids)
    assert eng.alloc.in_use == 0

    # a single request whose worst case exceeds the whole pool can NEVER
    # be admitted: submit refuses it up front
    tiny = ServeEngine(
        cfg, mesh, rules, params,
        EngineConfig(max_slots=2, max_len=16, kv_layout="paged",
                     page_size=4, num_blocks=4),   # 3 usable blocks
        aot=eng.aot,
    )
    with pytest.raises(ValueError):
        tiny.submit(np.arange(8), max_new_tokens=6)   # needs 4 blocks


def test_paged_engine_steady_builds_flat(setup):
    """Steady state on the paged path may not build executables — chunked
    prefill must not reintroduce per-length compiles."""
    cfg, mesh, rules, params = setup
    rng = np.random.default_rng(5)
    eng = ServeEngine(
        cfg, mesh, rules, params,
        EngineConfig(max_slots=2, max_len=32, kv_layout="paged",
                     page_size=8, prefill_chunk=4))
    eng.run(_prompts(cfg, rng, [3, 9, 14]), max_new_tokens=3)
    builds = eng.stats["builds"]
    # decode + first-chunk + continuation-chunk executables, nothing else
    assert builds == 3
    eng.run(_prompts(cfg, rng, [5, 13, 7, 2]), max_new_tokens=4)
    assert eng.stats["builds"] == builds


def test_paged_engine_validation(setup):
    cfg, mesh, rules, params = setup
    with pytest.raises(ValueError):
        ServeEngine(cfg, mesh, rules, params,
                    EngineConfig(kv_layout="bogus"))
    with pytest.raises(ValueError):
        ServeEngine(cfg, mesh, rules, params,
                    EngineConfig(kv_layout="slotted", prefill_chunk=8))
    with pytest.raises(ValueError):   # max_len not a multiple of page_size
        ServeEngine(cfg, mesh, rules, params,
                    EngineConfig(max_len=30, kv_layout="paged", page_size=8))
