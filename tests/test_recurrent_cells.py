"""SSD (Mamba2) and mLSTM chunked forms vs step-recurrence oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.ssm import ssd_chunked, ssd_decode_step, ssd_reference
from repro.models.xlstm import (
    mlstm_chunked, mlstm_decode_step, mlstm_reference, slstm_scan,
)


def _ssd_inputs(B, T, H, P, G, N, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(B, T, H, P)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(B, T, H)).astype(np.float32))
    A = jnp.asarray(-rng.uniform(0.5, 2.0, size=(H,)).astype(np.float32))
    Bm = jnp.asarray(rng.normal(size=(B, T, G, N)).astype(np.float32))
    Cm = jnp.asarray(rng.normal(size=(B, T, G, N)).astype(np.float32))
    return x, dt, A, Bm, Cm


@settings(max_examples=12, deadline=None)
@given(chunk=st.sampled_from([4, 8, 16, 32]), G=st.sampled_from([1, 2]))
def test_ssd_chunked_vs_recurrence(chunk, G):
    x, dt, A, Bm, Cm = _ssd_inputs(2, 32, 4, 8, G, 8, seed=chunk)
    ref = ssd_reference(x, dt, A, Bm, Cm)
    y = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=2e-4, rtol=2e-4)


def test_ssd_final_state_continues_decode():
    """prefill state -> decode steps must equal one long scan."""
    x, dt, A, Bm, Cm = _ssd_inputs(1, 24, 2, 4, 1, 4)
    ref = ssd_reference(x, dt, A, Bm, Cm)
    y, S = ssd_chunked(x[:, :16], dt[:, :16], A, Bm[:, :16], Cm[:, :16],
                       chunk=8, return_state=True)
    for t in range(16, 24):
        S, yt = ssd_decode_step(S, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t])
        np.testing.assert_allclose(np.asarray(yt), np.asarray(ref[:, t]),
                                   atol=2e-4, rtol=2e-4)


@settings(max_examples=10, deadline=None)
@given(chunk=st.sampled_from([4, 8, 16, 64]))
def test_mlstm_chunked_vs_recurrence(chunk):
    rng = np.random.default_rng(chunk)
    B, T, H, Dh = 2, 64, 3, 16
    q = jnp.asarray(rng.normal(size=(B, T, H, Dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, T, H, Dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, T, H, Dh)).astype(np.float32))
    i_pre = jnp.asarray(rng.normal(size=(B, T, H)).astype(np.float32) * 2)
    f_pre = jnp.asarray(rng.normal(size=(B, T, H)).astype(np.float32) * 2 + 1)
    ref = mlstm_reference(q, k, v, i_pre, f_pre)
    y = mlstm_chunked(q, k, v, i_pre, f_pre, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=3e-4, rtol=3e-3)


def test_mlstm_state_continues_decode():
    rng = np.random.default_rng(9)
    B, T, H, Dh = 1, 24, 2, 8
    mk = lambda *s: jnp.asarray(rng.normal(size=s).astype(np.float32))
    q, k, v = mk(B, T, H, Dh), mk(B, T, H, Dh), mk(B, T, H, Dh)
    i_pre, f_pre = mk(B, T, H), mk(B, T, H) + 1
    ref = mlstm_reference(q, k, v, i_pre, f_pre)
    y, st = mlstm_chunked(q[:, :16], k[:, :16], v[:, :16],
                          i_pre[:, :16], f_pre[:, :16], chunk=8,
                          return_state=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref[:, :16]),
                               atol=3e-4, rtol=3e-3)
    for t in range(16, 24):
        st, yt = mlstm_decode_step(st, q[:, t], k[:, t], v[:, t],
                                   i_pre[:, t], f_pre[:, t])
        np.testing.assert_allclose(np.asarray(yt), np.asarray(ref[:, t]),
                                   atol=3e-4, rtol=3e-3)


def test_slstm_scan_state_continuity():
    rng = np.random.default_rng(3)
    B, T, H, Dh = 2, 12, 2, 4
    mk = lambda *s: jnp.asarray(rng.normal(size=s).astype(np.float32))
    xs = [mk(B, T, H, Dh) for _ in range(4)]
    rs = [mk(H, Dh, Dh) * 0.1 for _ in range(4)]
    z = jnp.zeros((B, H, Dh), jnp.float32)
    m0 = jnp.full((B, H, Dh), -1e30, jnp.float32)
    full, _ = slstm_scan(*xs, *rs, z, z, z, m0)
    h1, st = slstm_scan(*[x[:, :6] for x in xs], *rs, z, z, z, m0)
    h2, _ = slstm_scan(*[x[:, 6:] for x in xs], *rs, *st)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([h1, h2], axis=1)), np.asarray(full),
        atol=1e-5, rtol=1e-5)
    assert np.all(np.isfinite(np.asarray(full)))
