"""Validation of the trip-count-aware HLO cost analyzer against
``compiled.cost_analysis()`` on unrolled probes (where XLA's counts are
exact), plus collective wire-byte accounting on known programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import model_flops, roofline_terms
from repro.roofline.hlo_cost import analyze

ONE_MM = 2 * 128 * 128 * 128


def _xla_cost(compiled) -> dict:
    """compiled.cost_analysis() returns a dict on new JAX, [dict] on old."""
    ca = compiled.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


def _probe(L, unroll):
    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, ws, unroll=unroll)
        return c
    xs = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, 128, 128), jnp.float32)
    return jax.jit(f).lower(xs, ws).compile()


@pytest.mark.parametrize("L", [2, 5, 8])
def test_rolled_scan_matches_unrolled_xla_counts(L):
    mine = analyze(_probe(L, 1).as_text())
    xla_unrolled = _xla_cost(_probe(L, L))["flops"]
    # dot flops must match exactly; elementwise accounting adds ~2%
    assert abs(mine.flops - xla_unrolled) / xla_unrolled < 0.05
    assert mine.flops >= L * ONE_MM


def test_nested_scan_trip_count_product():
    def g(x, ws):
        def outer(c, w):
            def inner(c2, _):
                return jnp.tanh(c2 @ w), None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        c, _ = jax.lax.scan(outer, x, ws)
        return c
    xs = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 128, 128), jnp.float32)
    hc = analyze(jax.jit(g).lower(xs, ws).compile().as_text())
    assert abs(hc.flops - 15 * ONE_MM) / (15 * ONE_MM) < 0.05


def test_xla_cost_analysis_undercounts_loops():
    """The reason hlo_cost exists: XLA counts while bodies once."""
    rolled = _probe(8, 1)
    assert _xla_cost(rolled)["flops"] < 2 * ONE_MM  # counted once


def test_collective_wire_bytes_all_reduce():
    import os
    # single-device: no collectives
    def f(x):
        return jnp.sum(x * 2.0)
    comp = jax.jit(f).lower(jax.ShapeDtypeStruct((64,), jnp.float32)).compile()
    hc = analyze(comp.as_text())
    assert hc.collective_summary()["total_wire_bytes"] == 0


def test_wire_byte_formulas():
    from repro.roofline.hlo_cost import _wire_bytes
    R, g = 1024, 8
    assert _wire_bytes("all-gather", R, g) == int(R * 7 / 8)
    assert _wire_bytes("all-reduce", R, g) == int(2 * R * 7 / 8)
    assert _wire_bytes("reduce-scatter", R, g) == R * 7
    assert _wire_bytes("collective-permute", R, g) == R
    assert _wire_bytes("all-reduce", R, 1) == 0


def test_model_flops_accounting():
    from repro.configs import get_config
    from repro.configs.base import SHAPES
    cfg = get_config("qwen3-moe-30b-a3b")
    mf_train = model_flops(cfg, SHAPES["train_4k"])
    mf_decode = model_flops(cfg, SHAPES["decode_32k"])
    # MoE: active params (top-8 of 128) << total
    assert cfg.n_params_active < 0.2 * cfg.n_params_dense
    assert mf_train == 6.0 * cfg.n_params_active * 256 * 4096
    assert mf_decode == 2.0 * cfg.n_params_active * 128


def test_roofline_terms_dominance():
    t = roofline_terms({"flops": 197e12, "bytes accessed": 1e9}, 0, n_chips=1)
    assert t["dominant"] == "compute_s"
    assert t["compute_s"] == pytest.approx(1.0)
    t = roofline_terms({"flops": 1e9, "bytes accessed": 819e9}, 0, n_chips=1)
    assert t["dominant"] == "memory_s"
    t = roofline_terms({"flops": 0, "bytes accessed": 0}, 50e9, n_chips=1)
    assert t["dominant"] == "collective_s"
    assert t["bound_s"] == pytest.approx(1.0)
