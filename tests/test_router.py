"""Router front-end tests: routing policy, crash failover, load
shedding, zero-downtime drain.

The headline property (chaos fuzz): seeded episodes that kill or stall a
replica mid-episode must end with every non-shed, non-cancelled request
completed ``"ok"`` on a surviving replica, greedy token streams
bitwise-identical to a fault-free single-engine run of the same trace,
zero requests lost, and ``steady_builds_delta == 0`` on the shared AOT
cache — fleet-level fault tolerance composed entirely from the engine's
preempt-and-replay machinery, so it inherits the PR-4/6 bitwise
guarantee.

Episode count: ``ROUTER_FUZZ_EPISODES`` env var (default below);
``scripts/ci.sh`` runs a larger sweep.
"""
import dataclasses
import os

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.aot import AotCache
from repro.models import registry
from repro.serve import EngineConfig, FaultPlan, ServeEngine
from repro.serve.router import Router, RouterConfig

from test_engine_fuzz import _FakeClock, drive, make_stream

EPISODES = int(os.environ.get("ROUTER_FUZZ_EPISODES", "6"))
MAX_SLOTS, MAX_LEN, BS = 3, 48, 8
SLOTTED = EngineConfig(max_slots=MAX_SLOTS, max_len=MAX_LEN)
PREFIX = EngineConfig(max_slots=2, max_len=MAX_LEN, kv_layout="paged",
                      page_size=BS, prefix_cache=True)
TIERED = EngineConfig(max_slots=2, max_len=MAX_LEN, kv_layout="paged",
                      page_size=BS, host_tier=True)


@pytest.fixture(scope="module")
def setup():
    from repro.launch.mesh import single_device_mesh
    from repro.models.common import ShardRules

    mesh = single_device_mesh()
    rules = ShardRules.for_mesh(mesh)
    cfg = dataclasses.replace(
        get_smoke_config("smollm-360m"), compute_dtype="float32")
    params = registry.get_module(cfg).init(cfg, jax.random.PRNGKey(0))
    aot = AotCache("router-test")
    # prebuild both engine shapes once: every router below must then
    # serve (and fail over, and drain) without a single fresh compile
    for ec in (SLOTTED, PREFIX, TIERED):
        ServeEngine(cfg, mesh, rules, params, ec, aot=aot).prebuild()
    return cfg, mesh, rules, params, aot


def mk_router(setup, ec=SLOTTED, *, replicas=3, shed=10_000, clock=None,
              faults=None, **rc_kw):
    cfg, mesh, rules, params, aot = setup
    kw = {} if clock is None else {"clock": clock}
    return Router(
        cfg, mesh, rules, params, ec,
        RouterConfig(replicas=replicas, shed_queue_depth=shed, **rc_kw),
        aot=aot, faults=faults, **kw)


def drive_router(router, stream, *, check=True, max_ticks=3000):
    """Replay a (tick, prompt, budget) stream through the router, one
    router tick per stream tick, sweeping fleet invariants."""
    i, tick = 0, 0
    while i < len(stream) or router.has_work():
        while i < len(stream) and stream[i][0] <= tick:
            _, prompt, budget = stream[i]
            router.submit(prompt, max_new_tokens=budget, rid=i)
            i += 1
        router.step()
        if check:
            router.check_invariants()
        tick += 1
        assert tick < max_ticks, "router failed to drain (livelock?)"
    return [list(router.completions[r].tokens) for r in range(len(stream))]


# ---------------------------------------------------------------------------
# The chaos fuzz (the acceptance property)
# ---------------------------------------------------------------------------

def test_fuzz_router_chaos(setup):
    cfg, mesh, rules, params, aot = setup
    builds0 = aot.stats["builds"]
    crashes = stalls = failovers = 0
    for seed in range(EPISODES):
        rng = np.random.default_rng(12000 + seed)
        stream = make_stream(rng, cfg.vocab)
        want, _ = drive(cfg, mesh, rules, params, aot, SLOTTED, stream)
        # max_fires=2 over 3 replicas: at least one survivor always
        # remains, so every episode must fully drain
        plan = FaultPlan(seed, {"replica_crash": 0.05,
                                "replica_stall": 0.05}, max_fires=2)
        router = mk_router(setup, replicas=3, faults=plan)
        got = drive_router(router, stream)
        assert got == want, (
            f"episode seed={seed}: router fleet diverged from the "
            f"fault-free single-engine stream\n  want={want}\n  got ={got}")
        assert all(c.status == "ok" for c in router.completions.values()), (
            f"episode seed={seed}: non-ok completions "
            f"{[(r, c.status, c.error) for r, c in router.completions.items() if c.status != 'ok']}")
        # zero requests lost: every submitted rid is terminal
        assert len(router.completions) == len(stream)
        assert router.counters["submitted"] == len(stream)
        # every surviving replica's own invariants held to the end; a
        # drained fleet holds nothing
        assert not router.records and not router.queue
        crashes += plan.fired["replica_crash"]
        stalls += plan.fired["replica_stall"]
        failovers += router.counters["failovers"]
    assert aot.stats["builds"] == builds0, (
        "failover replays forced fresh compiles — survivors must serve "
        "migrated requests purely from the shared cache")
    # vacuity guard: the schedules must actually kill/stall replicas
    if EPISODES >= 4:
        assert crashes + stalls > 0, "no replica fault fired in any episode"
        assert failovers > 0, "no request ever failed over"


def test_router_determinism(setup):
    """Same stream + same fault seed => same placements, same failovers,
    same tokens — router chaos failures replay by seed number."""
    cfg, mesh, rules, params, aot = setup
    stream = make_stream(np.random.default_rng(4242), cfg.vocab)
    runs = []
    for _ in range(2):
        plan = FaultPlan(7, {"replica_crash": 0.1}, max_fires=1)
        router = mk_router(setup, replicas=3, faults=plan)
        toks = drive_router(router, stream)
        runs.append((toks, dict(router.placements), router.counters.copy()))
    assert runs[0] == runs[1]


# ---------------------------------------------------------------------------
# Routing policy
# ---------------------------------------------------------------------------

def test_least_loaded_spreads_burst(setup):
    """A same-tick burst spreads across replicas instead of piling onto
    replica 0 (load counts in-flight work, ties break to lowest idx)."""
    router = mk_router(setup, replicas=3)
    rng = np.random.default_rng(1)
    for i in range(6):
        router.submit(rng.integers(0, 100, 8).astype(np.int32),
                      max_new_tokens=4, rid=i)
    router.step()
    assert [router.placements[i] for i in range(3)] == [0, 1, 2]
    router.run()
    assert all(c.status == "ok" for c in router.completions.values())


def test_cache_aware_routing(setup):
    """With prefix-cached engines, a prompt sharing a published chain
    follows it to the replica that owns the blocks — even when plain
    least-loaded (idle fleet, ties to lowest idx) would pick replica 0."""
    cfg = setup[0]
    router = mk_router(setup, PREFIX, replicas=2)
    rng = np.random.default_rng(2)
    pa = rng.integers(0, cfg.vocab, 2 * BS).astype(np.int32)
    pb = rng.integers(0, cfg.vocab, 2 * BS).astype(np.int32)
    ra = router.submit(pa, max_new_tokens=4)
    rb = router.submit(pb, max_new_tokens=4)
    router.run()
    assert (router.placements[ra], router.placements[rb]) == (0, 1)
    # c extends b's prefix; the fleet is idle, so least-loaded alone
    # would send it to replica 0 — cache-awareness must override
    pc = np.concatenate(
        [pb, rng.integers(0, cfg.vocab, 4).astype(np.int32)])
    rc = router.submit(pc, max_new_tokens=4)
    router.run()
    assert router.placements[rc] == router.placements[rb] == 1
    assert router.counters["cache_routed"] >= 1
    assert all(c.status == "ok" for c in router.completions.values())
    router.check_invariants()


# ---------------------------------------------------------------------------
# Graceful degradation: load shedding
# ---------------------------------------------------------------------------

def test_bounded_queue_sheds(setup):
    """Submissions beyond shed_queue_depth terminate immediately with
    status "shed" (structured, never an exception); queued work
    completes untouched."""
    router = mk_router(setup, replicas=1, shed=2)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, 100, 8).astype(np.int32) for _ in range(6)]
    rids = [router.submit(p, max_new_tokens=4) for p in prompts]
    shed = [r for r in rids if r in router.completions]
    assert len(shed) == 4                   # depth 2: first two queued
    for r in shed:
        c = router.completions[r]
        assert c.status == "shed" and "queue full" in c.error
        assert c.tokens == []
    router.run()
    router.check_invariants()
    assert router.counters["status_shed"] == 4
    assert all(router.completions[r].status == "ok"
               for r in rids if r not in shed)


def test_deadline_aware_early_shed(setup):
    """A TTL the queue cannot possibly meet sheds at submission (free)
    instead of timing out after wasting a lane; a generous TTL queues."""
    clock = _FakeClock()
    router = mk_router(setup, replicas=1, shed=50, clock=clock)
    rng = np.random.default_rng(4)
    p = rng.integers(0, 100, 8).astype(np.int32)
    # prime the service-time EWMA with one completed request
    router.submit(p, max_new_tokens=8)
    while router.has_work():
        router.step()
        clock.t += 1.0
    assert router._ewma_service is not None
    for _ in range(7):                      # deep queue, no deadlines
        router.submit(p, max_new_tokens=8)
    tight = router.submit(p, max_new_tokens=8, deadline_s=0.5)
    loose = router.submit(p, max_new_tokens=8, deadline_s=10_000.0)
    c = router.completions[tight]
    assert c.status == "shed" and "deadline unreachable" in c.error
    assert loose not in router.completions  # queued, not shed
    while router.has_work():
        router.step()
        clock.t += 1.0
    router.check_invariants()
    assert router.completions[loose].status == "ok"


def test_deadline_shed_cold_start_never_false_sheds(setup):
    """The EWMA must not be seeded by a compile-contaminated completion:
    on a cold cache the first request's service time is dominated by AOT
    builds, and an EWMA seeded with it would shed every
    tight-but-feasible deadline of the first real wave on an otherwise
    idle, warm fleet.  Only completions whose dispatch->finish window saw
    zero fresh builds count as service-time samples."""
    cfg, mesh, rules, params, _ = setup
    aot = AotCache("router-coldstart")      # deliberately cold
    clock = _FakeClock()
    router = Router(cfg, mesh, rules, params, SLOTTED,
                    RouterConfig(replicas=1, shed_queue_depth=50),
                    aot=aot, clock=clock)
    rng = np.random.default_rng(11)
    p = rng.integers(0, 100, 8).astype(np.int32)
    first = router.submit(p, max_new_tokens=4)
    while router.has_work():
        router.step()
        clock.t += 60.0                     # compile-inflated wall time
    assert router.completions[first].status == "ok"
    assert aot.stats["builds"] > 0, "cold cache never compiled?"
    # the contaminated sample was discarded, not averaged in
    assert router._ewma_service is None
    # first real wave: deadlines a warm fleet trivially meets, but that
    # a 60s-per-request EWMA would have declared unreachable
    rids = [router.submit(p, max_new_tokens=4, deadline_s=30.0)
            for _ in range(3)]
    assert all(r not in router.completions for r in rids), \
        "tight-but-feasible first wave was shed on an idle warm fleet"
    while router.has_work():
        router.step()
        clock.t += 1.0
    router.check_invariants()
    assert all(router.completions[r].status == "ok" for r in rids)
    assert router.counters["status_shed"] == 0
    # warm, compile-clean completions DO seed the EWMA
    assert router._ewma_service is not None


# ---------------------------------------------------------------------------
# Crash failover: budgets and total fleet loss
# ---------------------------------------------------------------------------

def test_failover_budget_exhaustion(setup):
    """Every replica serving a request dying in sequence consumes the
    per-request failover budget; exhaustion is a structured "failed"."""
    router = mk_router(setup, replicas=2, max_failovers=1)
    rng = np.random.default_rng(5)
    rid = router.submit(rng.integers(0, 100, 8).astype(np.int32),
                        max_new_tokens=12)
    router.step()                           # placed + first tokens
    router.kill(router.placements[rid])     # failover 1: within budget
    router.check_invariants()
    router.step()                           # re-placed on the survivor
    assert rid not in router.completions
    router.kill(router.placements[rid])     # failover 2: budget blown
    c = router.completions[rid]
    assert c.status == "failed" and "failover budget" in c.error
    router.check_invariants()
    # the mirrored prefix survives onto the failed completion
    assert len(c.tokens) >= 1
    # total fleet loss: new submissions shed instead of queueing forever
    r2 = router.submit(rng.integers(0, 100, 8).astype(np.int32),
                       max_new_tokens=4)
    assert router.completions[r2].status == "shed"
    assert "no live replicas" in router.completions[r2].error


def test_failover_restores_from_shared_host_tier(setup):
    """The host tier is fleet-shared: a lane snapshot spilled by one
    replica survives that replica's crash (payloads are host arrays,
    rids are router-unique), so failover on the survivor restores
    O(copy) — zero replayed decode steps — instead of replaying the
    mirrored stream token by token."""
    cfg, mesh, rules, params, aot = setup
    rng = np.random.default_rng(14)
    p = rng.integers(0, cfg.vocab, 10).astype(np.int32)
    ref = ServeEngine(cfg, mesh, rules, params, TIERED, aot=aot)
    want = list(ref.run([p], max_new_tokens=12)[0])

    router = mk_router(setup, TIERED, replicas=2)
    assert router.tier is not None
    rid = router.submit(p, max_new_tokens=12)
    router.step()
    router.step()                           # genuinely mid-decode
    victim = router.placements[rid]
    eng = router.replicas[victim].engine
    assert len(eng.live[rid].tokens) >= 1
    # snapshot the lane into the fleet tier (the engine does this on
    # preempt; here we take it directly so the spill is provably fresh
    # at crash time), then kill the replica that owns the device state
    assert eng._spill_lane(eng._find_lane(rid))
    assert router.tier.has_lane(rid)
    router.kill(victim)
    router.check_invariants()
    router.run()
    surv = router.replicas[1 - victim].engine
    c = router.completions[rid]
    assert c.status == "ok"
    assert list(c.tokens) == want, "tier-restored failover diverged"
    assert router.counters["failovers"] == 1
    # restored O(copy): the survivor never replay-forced a token
    assert router.tier.lane_restores >= 1
    assert surv.counters["restores"] >= 1
    assert surv.counters["replayed_tokens"] == 0
    assert router.tier.has_lane(rid) is False   # moved out, not copied


def test_queued_work_fails_on_total_fleet_loss(setup):
    """Requests already queued when the last replica dies terminate
    "failed" on the next tick rather than being held hostage."""
    router = mk_router(setup, replicas=1, shed=50)
    rng = np.random.default_rng(6)
    rids = [router.submit(rng.integers(0, 100, 8).astype(np.int32),
                          max_new_tokens=4) for _ in range(5)]
    router.kill(0)
    router.step()
    router.check_invariants()
    assert all(router.completions[r].status == "failed" for r in rids)
    assert not router.has_work()


def test_stall_detection_budget(setup):
    """A stalled replica is only declared dead after stall_budget ticks
    without progress — and its requests then complete elsewhere with the
    exact fault-free stream."""
    cfg, mesh, rules, params, aot = setup
    rng = np.random.default_rng(7)
    stream = [(0, rng.integers(0, 100, 8).astype(np.int32), 6)
              for _ in range(4)]
    want, _ = drive(cfg, mesh, rules, params, aot, SLOTTED, stream)
    router = mk_router(setup, replicas=2, stall_budget=3)
    for i, (_, p, b) in enumerate(stream):
        router.submit(p, max_new_tokens=b, rid=i)
    router.step()
    router.replicas[0].stalled = True       # hang, not crash
    ticks_before_dead = 0
    while router.replicas[0].state != "dead":
        router.step()
        router.check_invariants()
        ticks_before_dead += 1
        assert ticks_before_dead < 20
    assert ticks_before_dead >= router.rc.stall_budget - 1
    assert router.counters["stalls_detected"] == 1
    router.run()
    got = [list(router.completions[i].tokens) for i in range(len(stream))]
    assert got == want
    assert all(c.status == "ok" for c in router.completions.values())


# ---------------------------------------------------------------------------
# Zero-downtime drain
# ---------------------------------------------------------------------------

def test_drain_migrates_and_preserves_streams(setup):
    cfg, mesh, rules, params, aot = setup
    stream = make_stream(np.random.default_rng(8), cfg.vocab)
    want, _ = drive(cfg, mesh, rules, params, aot, SLOTTED, stream)
    router = mk_router(setup, replicas=2)
    for i, (_, p, b) in enumerate(stream):
        router.submit(p, max_new_tokens=b, rid=i)
    for _ in range(3):                      # mid-decode on both replicas
        router.step()
    moved = router.drain(0)
    assert moved == router.counters["migrated"] > 0
    assert router.replicas[0].state == "drained"
    assert not router.replicas[0].engine.has_work()
    router.check_invariants()
    router.run()
    got = [list(router.completions[i].tokens) for i in range(len(stream))]
    assert got == want, "drain perturbed a migrated stream"
    assert all(c.status == "ok" for c in router.completions.values())
    # nothing places on a drained replica; reinstate returns it to rotation
    rng = np.random.default_rng(9)
    ra = router.submit(rng.integers(0, 100, 8).astype(np.int32),
                       max_new_tokens=4)
    router.run()
    assert router.placements[ra] == 1
    router.reinstate(0)
    rb = router.submit(rng.integers(0, 100, 8).astype(np.int32),
                       max_new_tokens=4)
    router.run()
    assert router.placements[rb] == 0
    router.drain(0)                         # idle drain: fine, moves 0
    with pytest.raises(ValueError, match="not live"):
        router.drain(0)                     # already drained


def test_drain_requires_live_replica(setup):
    router = mk_router(setup, replicas=2)
    router.kill(1)
    with pytest.raises(ValueError, match="dead"):
        router.drain(1)


# ---------------------------------------------------------------------------
# Engine-level migration primitives
# ---------------------------------------------------------------------------

def test_export_import_roundtrip_mid_decode(setup):
    """export_request off a mid-decode lane, import into a different
    engine, finish there: tokens bitwise the uninterrupted stream."""
    cfg, mesh, rules, params, aot = setup
    rng = np.random.default_rng(10)
    pa = rng.integers(0, cfg.vocab, 10).astype(np.int32)
    pb = rng.integers(0, cfg.vocab, 6).astype(np.int32)
    ref = ServeEngine(cfg, mesh, rules, params, SLOTTED, aot=aot)
    want_a = list(ref.run([pa], max_new_tokens=8)[0])
    want_b = list(ref.run([pb], max_new_tokens=5)[0])

    src = ServeEngine(cfg, mesh, rules, params, SLOTTED, aot=aot)
    dst = ServeEngine(cfg, mesh, rules, params, SLOTTED, aot=aot)
    ra = src.submit(pa, max_new_tokens=8)
    rb = src.submit(pb, max_new_tokens=5, rid=77)
    src.step()
    src.step()
    assert len(src.live[ra].tokens) >= 1    # genuinely mid-decode
    snap = src.export_request(ra)
    assert snap["pending"]["resume"] is True
    assert snap["completion"] is not None
    src.check_invariants()
    assert ra not in src.live
    dst.import_request(snap)
    dst.check_invariants()
    src.drain()
    dst.drain()
    assert list(dst.completions[ra].tokens) == want_a
    assert dst.completions[ra].status == "ok"
    assert list(src.completions[rb].tokens) == want_b
    assert src.counters["exported"] == 1
    assert dst.counters["imported"] == 1


def test_export_import_error_cases(setup):
    cfg, mesh, rules, params, aot = setup
    eng = ServeEngine(cfg, mesh, rules, params, SLOTTED, aot=aot)
    rid = eng.submit(np.arange(1, 7, dtype=np.int32), max_new_tokens=2)
    with pytest.raises(KeyError):
        eng.export_request(rid + 999)
    snap = eng.export_request(rid)          # still queued: fresh export
    assert snap["pending"]["resume"] is False
    assert snap["completion"] is None
    eng.import_request(snap)                # back home
    with pytest.raises(ValueError, match="already known"):
        eng.import_request(snap)
    eng.drain()
    with pytest.raises(ValueError, match="terminal"):
        eng.export_request(rid)
    # resume without its Completion is structurally invalid
    bad = {"pending": dict(snap["pending"], resume=True),
           "completion": None}
    with pytest.raises(ValueError, match="without its"):
        eng.import_request(dict(bad, pending=dict(bad["pending"], rid=555)))


# ---------------------------------------------------------------------------
# FaultPlan victim substream (satellite: schedule depends on consult
# order only)
# ---------------------------------------------------------------------------

def test_pick_victim_substream_does_not_perturb_fire_schedule():
    """pick() draws victims from a separate (seed, site, victim)
    substream, so a fire() consult sequence and a pick() consult
    sequence at the same seed see the IDENTICAL fire/skip schedule —
    firing (which also draws a victim) must not re-time later fires."""
    rates = {"replica_crash": 0.4}
    a = FaultPlan(123, rates)
    b = FaultPlan(123, rates)
    fired_a = [a.fire("replica_crash") for _ in range(50)]
    fired_b = [b.pick("replica_crash", [0, 1, 2]) is not None
               for _ in range(50)]
    assert fired_a == fired_b
    assert any(fired_a)
    # and victims are deterministic per seed
    c = FaultPlan(123, rates)
    d = FaultPlan(123, rates)
    assert [c.pick("replica_crash", [0, 1, 2]) for _ in range(50)] \
        == [d.pick("replica_crash", [0, 1, 2]) for _ in range(50)]


def test_replica_sites_extend_engine_sites():
    """Appending the replica sites kept the engine sites' stream indices
    (seeded by position), so engine chaos schedules are unchanged."""
    from repro.serve import ENGINE_FAULT_SITES, FAULT_SITES, \
        REPLICA_FAULT_SITES
    assert FAULT_SITES == ENGINE_FAULT_SITES + REPLICA_FAULT_SITES
    assert FAULT_SITES[:4] == ("decode_logits", "prefill", "alloc",
                               "sched_push")
