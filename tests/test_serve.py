"""Serving loop: greedy determinism, batch independence, temperature."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import registry
from repro.serve import ServeConfig, generate


@pytest.fixture(scope="module")
def setup(request):
    import jax
    from repro.launch.mesh import single_device_mesh
    from repro.models.common import ShardRules
    mesh = single_device_mesh()
    rules = ShardRules.for_mesh(mesh)
    cfg = get_smoke_config("smollm-360m")
    params = registry.get_module(cfg).init(cfg, jax.random.PRNGKey(0))
    return cfg, mesh, rules, params


def test_greedy_decode_deterministic(setup):
    cfg, mesh, rules, params = setup
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (3, 12)).astype(np.int32)
    a = generate(cfg, mesh, rules, params, prompts, serve=ServeConfig(max_new_tokens=6))
    b = generate(cfg, mesh, rules, params, prompts, serve=ServeConfig(max_new_tokens=6))
    np.testing.assert_array_equal(a, b)
    assert a.shape == (3, 6)
    assert np.all((a >= 0) & (a < cfg.vocab))


def test_batch_independence(setup):
    """A sequence's continuation must not depend on its batchmates."""
    cfg, mesh, rules, params = setup
    rng = np.random.default_rng(1)
    p0 = rng.integers(0, cfg.vocab, (1, 12)).astype(np.int32)
    noise = rng.integers(0, cfg.vocab, (2, 12)).astype(np.int32)
    alone = generate(cfg, mesh, rules, params, p0, serve=ServeConfig(max_new_tokens=5))
    together = generate(cfg, mesh, rules, params,
                        np.concatenate([p0, noise]), serve=ServeConfig(max_new_tokens=5))
    np.testing.assert_array_equal(alone[0], together[0])


def test_temperature_sampling_varies(setup):
    cfg, mesh, rules, params = setup
    prompts = np.random.default_rng(2).integers(0, cfg.vocab, (2, 8)).astype(np.int32)
    a = generate(cfg, mesh, rules, params, prompts,
                 serve=ServeConfig(max_new_tokens=8, temperature=2.0, seed=1))
    b = generate(cfg, mesh, rules, params, prompts,
                 serve=ServeConfig(max_new_tokens=8, temperature=2.0, seed=2))
    assert not np.array_equal(a, b)
