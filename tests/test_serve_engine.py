"""Continuous-batching serve engine: admit/evict invariants, per-slot
decode correctness vs the static loop, prefill bucket reuse (flat build
counter), fused-sampler determinism, EOS eviction, and AotCache counters
for both the train (SynkFunction) and serve callers."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.aot import AotCache
from repro.models import registry
from repro.serve import (
    EngineConfig,
    RecurrentCache,
    ServeConfig,
    ServeEngine,
    bucket_for,
    generate,
    generate_static,
    prompt_buckets,
)


@pytest.fixture(scope="module")
def setup():
    from repro.launch.mesh import single_device_mesh
    from repro.models.common import ShardRules

    mesh = single_device_mesh()
    rules = ShardRules.for_mesh(mesh)
    # f32 so greedy comparisons against the static loop are exact
    cfg = dataclasses.replace(
        get_smoke_config("smollm-360m"), compute_dtype="float32")
    params = registry.get_module(cfg).init(cfg, jax.random.PRNGKey(0))
    return cfg, mesh, rules, params


def _family_setup(arch: str):
    from repro.launch.mesh import single_device_mesh
    from repro.models.common import ShardRules

    mesh = single_device_mesh()
    rules = ShardRules.for_mesh(mesh)
    cfg = dataclasses.replace(
        get_smoke_config(arch), compute_dtype="float32")
    params = registry.get_module(cfg).init(cfg, jax.random.PRNGKey(0))
    return cfg, mesh, rules, params


@pytest.fixture(scope="module")
def rec_setup():
    """xLSTM smoke config — the ``ssm`` family, state kind 'recurrent'."""
    return _family_setup("xlstm-1.3b")


@pytest.fixture(scope="module")
def hyb_setup():
    """Zamba2 smoke config — the ``hybrid`` family (KV + recurrent)."""
    return _family_setup("zamba2-1.2b")


def _prompts(cfg, rng, lens):
    return [rng.integers(0, cfg.vocab, n).astype(np.int32) for n in lens]


# ---------------------------------------------------------------------------
# Slot lifecycle
# ---------------------------------------------------------------------------


def test_admit_evict_invariants(setup):
    """Scripted schedule: more requests than slots, heterogeneous budgets.
    Slots never oversubscribe, every request gets exactly its budget, and
    the engine counters balance."""
    cfg, mesh, rules, params = setup
    rng = np.random.default_rng(0)
    budgets = [3, 1, 6, 2, 4, 2, 5, 1]
    prompts = _prompts(cfg, rng, [4, 9, 5, 12, 3, 7, 6, 4])
    eng = ServeEngine(cfg, mesh, rules, params,
                      EngineConfig(max_slots=3, max_len=32))
    rids = [eng.submit(p, max_new_tokens=b) for p, b in zip(prompts, budgets)]

    while eng.has_work():
        assert eng.step()
        occupied = sum(s is not None for s in eng.slots)
        assert occupied <= 3
        assert eng.counters["admitted"] - eng.counters["evicted"] == len(eng.live)
        assert len(eng.live) == occupied
    assert not eng.step()                       # idle engine reports no work

    assert eng.counters["admitted"] == eng.counters["evicted"] == len(budgets)
    assert eng.counters["admitted"] > 3         # slots were reused
    for r, b in zip(rids, budgets):
        c = eng.completions[r]
        assert len(c.tokens) == b
        assert all(0 <= t < cfg.vocab for t in c.tokens)


def test_staggered_matches_solo_static(setup):
    """THE continuous-batching correctness property: a request admitted
    mid-flight into a slot (at its own cache position, prompt padded to a
    bucket, batchmates at other positions) must produce exactly the tokens
    the legacy static loop produces for it alone."""
    cfg, mesh, rules, params = setup
    rng = np.random.default_rng(1)
    lens = [5, 11, 8]
    budgets = [7, 3, 5]
    prompts = _prompts(cfg, rng, lens)

    solo = [
        generate_static(cfg, mesh, rules, params, p[None],
                        serve=ServeConfig(max_new_tokens=b))[0]
        for p, b in zip(prompts, budgets)
    ]

    # 2 slots, 3 requests: the third is admitted when a lane frees, while
    # the surviving lane sits mid-sequence at a different length
    eng = ServeEngine(cfg, mesh, rules, params,
                      EngineConfig(max_slots=2, max_len=32))
    rids = [eng.submit(p, max_new_tokens=b) for p, b in zip(prompts, budgets)]
    eng.drain()
    for r, want in zip(rids, solo):
        got = np.asarray(eng.completions[r].tokens)
        np.testing.assert_array_equal(got, np.asarray(want))


def test_generate_wrapper_greedy_parity(setup):
    """generate() is a thin wrapper over the engine and must match the
    legacy loop token-for-token under greedy decoding."""
    cfg, mesh, rules, params = setup
    rng = np.random.default_rng(2)
    prompts = np.stack(_prompts(cfg, rng, [10, 10, 10]))
    a = generate(cfg, mesh, rules, params, prompts,
                 serve=ServeConfig(max_new_tokens=6))
    b = generate_static(cfg, mesh, rules, params, prompts,
                        serve=ServeConfig(max_new_tokens=6))
    np.testing.assert_array_equal(a, b)
    assert a.shape == (3, 6)


def test_generate_default_serveconfig_not_shared(setup):
    """The old signature had ``serve: ServeConfig = ServeConfig()`` — a
    mutable shared-instance footgun.  Defaulting must build a fresh config
    per call (None sentinel)."""
    import inspect
    from repro.serve import loop

    for fn in (loop.generate, loop.generate_static):
        default = inspect.signature(fn).parameters["serve"].default
        assert default is None


def test_eos_eviction(setup):
    """A lane hitting EOS frees immediately and its tokens end at EOS."""
    cfg, mesh, rules, params = setup
    rng = np.random.default_rng(3)
    prompt = _prompts(cfg, rng, [6])[0]
    # learn what greedy emits, then re-run with that token as EOS
    probe = ServeEngine(cfg, mesh, rules, params,
                        EngineConfig(max_slots=1, max_len=32))
    toks = probe.run([prompt], max_new_tokens=8)[0]
    eos = int(toks[2])

    eng = ServeEngine(cfg, mesh, rules, params,
                      EngineConfig(max_slots=1, max_len=32, eos_id=eos))
    out = eng.run([prompt], max_new_tokens=8)[0]
    assert out[-1] == eos
    assert len(out) <= len(toks)
    assert eos not in out[:-1]
    assert eng.counters["evicted"] == 1


# ---------------------------------------------------------------------------
# Dispatch-cache behavior
# ---------------------------------------------------------------------------


def test_prefill_bucket_reuse(setup):
    """Build count = one decode + one prefill per distinct *bucket*; more
    requests in the same buckets must not build anything new."""
    cfg, mesh, rules, params = setup
    rng = np.random.default_rng(4)
    eng = ServeEngine(cfg, mesh, rules, params,
                      EngineConfig(max_slots=2, max_len=64))
    assert eng.buckets == prompt_buckets(64) == (16, 32, 64)

    eng.run(_prompts(cfg, rng, [3, 9, 14]), max_new_tokens=2)   # bucket 16
    assert eng.stats["builds"] == 2                 # decode + prefill@16
    eng.run(_prompts(cfg, rng, [20, 17]), max_new_tokens=2)     # bucket 32
    assert eng.stats["builds"] == 3
    hits_before = eng.stats["cache_hits"]
    eng.run(_prompts(cfg, rng, [5, 21, 8, 30]), max_new_tokens=3)
    assert eng.stats["builds"] == 3                 # steady state: no builds
    assert eng.stats["cache_hits"] > hits_before
    assert eng.stats["executables"] == 3


def test_bucket_for():
    assert bucket_for(3, (16, 32)) == 16
    assert bucket_for(16, (16, 32)) == 16
    assert bucket_for(17, (16, 32)) == 32
    with pytest.raises(ValueError):
        bucket_for(33, (16, 32))
    assert prompt_buckets(10) == (10,)
    assert prompt_buckets(100) == (16, 32, 64, 100)


def test_bucket_degenerate_inputs():
    """Degenerate inputs must raise loudly instead of silently producing
    empty/garbage bucket tables."""
    with pytest.raises(ValueError):
        prompt_buckets(0)
    with pytest.raises(ValueError):
        prompt_buckets(-3)
    with pytest.raises(ValueError):
        prompt_buckets(64, min_bucket=0)
    with pytest.raises(ValueError):
        prompt_buckets(64, min_bucket=-16)
    with pytest.raises(ValueError):
        bucket_for(0, (16, 32))            # zero-length prompt
    with pytest.raises(ValueError):
        bucket_for(-1, (16, 32))
    with pytest.raises(ValueError):
        bucket_for(4, ())                  # no buckets configured


def test_aot_cache_counters_train_and_serve(setup):
    """The shared AotCache counts builds/hits for both caller families."""
    # unit
    c = AotCache("t")
    assert c.get(("a",), lambda: 41) == 41
    assert c.get(("a",), lambda: 43) == 41          # cached, build not rerun
    assert c.get(("b",), lambda: 42) == 42
    assert c.stats == {"builds": 2, "cache_hits": 1}
    assert len(c) == 2 and ("a",) in c

    # train caller: SynkFunction routes its executables through AotCache
    import repro.core as synk

    synk.reset()
    f = synk.function(lambda x: jnp.sum(x), [synk.Scatter()],
                      synk.Reduce("sum"))
    x = np.arange(8, dtype=np.float32)
    f(x); f(x)
    assert f.stats["builds"] == 1
    assert f.stats["cache_hits"] == 1
    f(np.arange(16, dtype=np.float32))              # new signature
    assert f.stats["builds"] == 2

    # serve caller: engine counters mirror the same schema
    cfg, mesh, rules, params = setup
    eng = ServeEngine(cfg, mesh, rules, params,
                      EngineConfig(max_slots=1, max_len=32))
    eng.run(_prompts(cfg, np.random.default_rng(5), [4]), max_new_tokens=4)
    assert eng.stats["builds"] == 2
    assert eng.stats["cache_hits"] >= 1
    for key in ("admitted", "evicted", "dead_slot_steps", "builds",
                "cache_hits"):
        assert key in eng.stats


# ---------------------------------------------------------------------------
# Fused sampler
# ---------------------------------------------------------------------------


def test_fused_sampler_deterministic_given_seed(setup):
    cfg, mesh, rules, params = setup
    rng = np.random.default_rng(6)
    prompts = _prompts(cfg, rng, [6, 9])

    def run(seed):
        eng = ServeEngine(cfg, mesh, rules, params,
                          EngineConfig(max_slots=2, max_len=32, seed=seed))
        return eng.run(prompts, max_new_tokens=8, temperature=1.5)

    a, b = run(seed=7), run(seed=7)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    c = run(seed=8)
    assert any(not np.array_equal(x, y) for x, y in zip(a, c))


def test_mixed_greedy_and_sampled_lanes(setup):
    """Greedy lanes must stay greedy (= static loop) even while a
    temperature>0 lane shares the decode executable."""
    cfg, mesh, rules, params = setup
    rng = np.random.default_rng(7)
    g_prompt, s_prompt = _prompts(cfg, rng, [8, 8])
    want = generate_static(cfg, mesh, rules, params, g_prompt[None],
                           serve=ServeConfig(max_new_tokens=5))[0]

    eng = ServeEngine(cfg, mesh, rules, params,
                      EngineConfig(max_slots=2, max_len=32))
    rid_g = eng.submit(g_prompt, max_new_tokens=5, temperature=0.0)
    eng.submit(s_prompt, max_new_tokens=5, temperature=2.0)
    eng.drain()
    np.testing.assert_array_equal(
        np.asarray(eng.completions[rid_g].tokens), np.asarray(want))


def test_sample_tokens_shapes():
    from repro.serve import sample_tokens

    logits = jnp.asarray(np.random.default_rng(0).normal(size=(4, 32)),
                         jnp.float32)
    key = jax.random.PRNGKey(0)
    greedy = sample_tokens(logits, key, jnp.zeros(4))
    np.testing.assert_array_equal(
        np.asarray(greedy), np.argmax(np.asarray(logits), -1))
    hot = sample_tokens(logits, key, jnp.full(4, 2.0), top_k=4)
    top4 = np.argsort(np.asarray(logits), -1)[:, -4:]
    for i in range(4):
        assert int(hot[i]) in top4[i]


def test_sample_tokens_per_slot_vectors():
    """Per-slot top_ks/top_ps vectors: row 0 unmasked, row 1 top-k=1
    (degenerates to greedy), row 2 tiny top-p (degenerates to greedy),
    row 3 top-k=4 — each row masked independently."""
    from repro.serve import sample_tokens

    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)
    greedy = np.argmax(np.asarray(logits), -1)
    key = jax.random.PRNGKey(1)
    out = sample_tokens(
        logits, key, jnp.full(4, 3.0),
        top_ks=jnp.asarray([0, 1, 0, 4], jnp.int32),
        top_ps=jnp.asarray([0.0, 0.0, 1e-6, 0.0], jnp.float32),
    )
    assert int(out[1]) == greedy[1]
    assert int(out[2]) == greedy[2]
    top4 = np.argsort(np.asarray(logits), -1)[:, -4:]
    assert int(out[3]) in top4[3]
    # off-vectors (0s) must not perturb the unmasked sampling path
    base = sample_tokens(logits, key, jnp.full(4, 3.0))
    masked_off = sample_tokens(
        logits, key, jnp.full(4, 3.0),
        top_ks=jnp.zeros(4, jnp.int32), top_ps=jnp.zeros(4, jnp.float32))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(masked_off))


def test_per_request_sampling_params(setup):
    """submit(top_k=, top_p=) land in the on-device per-slot vectors: a
    hot-temperature lane with top_k=1 (or a tiny top_p) must reproduce the
    greedy stream, while an unconstrained hot lane in the SAME batch
    diverges — all through the fused sampler, no host syncs added."""
    cfg, mesh, rules, params = setup
    rng = np.random.default_rng(8)
    p1, p2 = _prompts(cfg, rng, [8, 8])
    want = generate_static(cfg, mesh, rules, params, p1[None],
                           serve=ServeConfig(max_new_tokens=6))[0]

    eng = ServeEngine(cfg, mesh, rules, params,
                      EngineConfig(max_slots=2, max_len=32))
    rid_k = eng.submit(p1, max_new_tokens=6, temperature=2.0, top_k=1)
    rid_hot = eng.submit(p2, max_new_tokens=6, temperature=2.0)
    eng.drain()
    np.testing.assert_array_equal(
        np.asarray(eng.completions[rid_k].tokens), np.asarray(want))

    eng2 = ServeEngine(cfg, mesh, rules, params,
                       EngineConfig(max_slots=2, max_len=32))
    rid_p = eng2.submit(p1, max_new_tokens=6, temperature=2.0, top_p=1e-9)
    eng2.drain()
    np.testing.assert_array_equal(
        np.asarray(eng2.completions[rid_p].tokens), np.asarray(want))


def test_submit_validation(setup):
    cfg, mesh, rules, params = setup
    eng = ServeEngine(cfg, mesh, rules, params,
                      EngineConfig(max_slots=1, max_len=16))
    with pytest.raises(ValueError):
        eng.submit(np.arange(20), max_new_tokens=2)     # prompt > max bucket
    with pytest.raises(ValueError):
        eng.submit(np.arange(4), max_new_tokens=14)     # overruns max_len
    with pytest.raises(ValueError):
        eng.submit(np.array([], np.int32))


def test_host_vs_fused_sampler_parity(setup):
    """The host-sampling ablation now carries full per-request sampling
    (temperature + top-k + top-p): it draws from a host mirror of the
    device key stream and runs the same ``sample_tokens`` math, so at a
    fixed engine seed it reproduces the fused path token-for-token —
    including stochastic lanes."""
    cfg, mesh, rules, params = setup
    rng = np.random.default_rng(9)
    prompts = _prompts(cfg, rng, [6, 9, 4])
    samplers = [dict(temperature=1.5, top_k=8),
                dict(temperature=2.0, top_p=0.9),
                dict(temperature=0.0)]           # greedy lane rides along

    def run(fused):
        eng = ServeEngine(cfg, mesh, rules, params,
                          EngineConfig(max_slots=2, max_len=32, seed=3,
                                       fused_sampling=fused))
        rids = [eng.submit(p, max_new_tokens=5, **kw)
                for p, kw in zip(prompts, samplers)]
        eng.drain()
        return [eng.completions[r].tokens for r in rids]

    fused, host = run(True), run(False)
    assert fused == host
    # the stochastic lanes really sampled (not all-greedy degenerate)
    solo = ServeEngine(cfg, mesh, rules, params,
                       EngineConfig(max_slots=2, max_len=32, seed=4))
    greedy = [list(t) for t in solo.run(prompts[:2], max_new_tokens=5)]
    assert fused[:2] != greedy


# ---------------------------------------------------------------------------
# Recurrent state kinds: ssm (xLSTM) + hybrid (Zamba)
# ---------------------------------------------------------------------------


def _staggered_vs_solo(cfg, mesh, rules, params):
    """Shared body: 3 requests through 2 lanes (the third admitted only
    when a lane frees, its batchmate mid-sequence at a different length)
    must reproduce each request's solo ``generate_static`` stream."""
    rng = np.random.default_rng(1)
    lens = [5, 11, 8]
    budgets = [7, 3, 5]
    prompts = _prompts(cfg, rng, lens)
    solo = [
        generate_static(cfg, mesh, rules, params, p[None],
                        serve=ServeConfig(max_new_tokens=b))[0]
        for p, b in zip(prompts, budgets)
    ]
    eng = ServeEngine(cfg, mesh, rules, params,
                      EngineConfig(max_slots=2, max_len=32))
    rids = [eng.submit(p, max_new_tokens=b) for p, b in zip(prompts, budgets)]
    eng.drain()
    for r, want in zip(rids, solo):
        np.testing.assert_array_equal(
            np.asarray(eng.completions[r].tokens), np.asarray(want))
    return eng


def test_recurrent_staggered_matches_solo_static(rec_setup):
    """The continuous-batching property for a RECURRENT cache: lanes are
    per-lane (ssm_state, conv_state)/mLSTM-state leaves with no seq axis,
    admission snapshots the state at the real prompt end despite bucket
    padding, and staggered decode matches solo static token-for-token."""
    eng = _staggered_vs_solo(*rec_setup)
    assert eng.kind == "recurrent"
    assert eng.stats["state_kind"] == "recurrent"


def test_hybrid_staggered_matches_solo_static(hyb_setup):
    """Zamba lanes compose BOTH state kinds — a slotted KV segment for the
    shared attention block and recurrent mamba leaves — through one cache
    dict; the engine serves them with the same admission/eviction flow."""
    eng = _staggered_vs_solo(*hyb_setup)
    assert eng.kind == "hybrid"
    # the composed cache really holds both kinds
    assert set(eng.rec.leaf_axes) == {"ssm", "conv"}
    assert "k" in eng.state["cache"] and "v" in eng.state["cache"]


@pytest.mark.parametrize("fixture", ["rec_setup", "hyb_setup"])
def test_recurrent_cache_admit_evict_zeroing(fixture, request):
    """RecurrentCache lifecycle invariants: admission hard-resets a lane
    (fresh snapshot, nothing of the previous occupant), decode freezes
    inactive lanes at zero (evict-time zeroing fused into the decode
    executable), and a drained engine's recurrent leaves are all-zero."""
    cfg, mesh, rules, params = request.getfixturevalue(fixture)
    rng = np.random.default_rng(2)
    eng = ServeEngine(cfg, mesh, rules, params,
                      EngineConfig(max_slots=2, max_len=32))
    assert eng.rec and set(eng.rec.leaf_axes) == set(
        registry.recurrent_leaf_axes(cfg))

    # all lanes start zero
    for i in range(2):
        assert eng.rec.lane_is_zero(eng.state["cache"], i)

    # run a short request next to a long one: the short lane evicts while
    # the long one keeps decoding — its lane must read exactly zero while
    # the survivor's state is non-zero
    p_long, p_short = _prompts(cfg, rng, [6, 4])
    rid_long = eng.submit(p_long, max_new_tokens=10)
    rid_short = eng.submit(p_short, max_new_tokens=2)
    steps = 0
    while rid_short not in eng.completions:
        assert eng.step()
        eng.check_invariants()
        steps += 1
        assert steps < 50
    assert rid_long in eng.live                 # the long lane still decodes
    short_slot = next(i for i, s in enumerate(eng.slots) if s is None)
    live_slot = 1 - short_slot
    assert eng.rec.lane_is_zero(eng.state["cache"], short_slot)
    assert not eng.rec.lane_is_zero(eng.state["cache"], live_slot)

    # admit-time reset: a new request takes over the freed lane and its
    # stream matches solo static — no state of the previous occupant leaks
    p_new = _prompts(cfg, rng, [7])[0]
    want = generate_static(cfg, mesh, rules, params, p_new[None],
                           serve=ServeConfig(max_new_tokens=4))[0]
    rid_new = eng.submit(p_new, max_new_tokens=4)
    eng.drain()
    np.testing.assert_array_equal(
        np.asarray(eng.completions[rid_new].tokens), np.asarray(want))

    # evict-time zeroing: a drained engine holds all-zero recurrent state
    for i in range(2):
        assert eng.rec.lane_is_zero(eng.state["cache"], i)
    assert eng.counters["evicted"] == 3


def test_recurrent_preempt_resume_parity(rec_setup):
    """Preempt-and-requeue for the ssm family: a lane preempted mid-decode
    resumes by re-prefilling ONLY the prompt (bucketed prefill of a
    recurrent state is deterministic, so the snapshot is bitwise) and
    replaying its emitted tokens through decode — the PR-4 policy — and
    the resumed stream equals the unpreempted one token-for-token."""
    cfg, mesh, rules, params = rec_setup
    rng = np.random.default_rng(3)
    prompts = _prompts(cfg, rng, [6, 9])

    def run(preempt_at):
        eng = ServeEngine(cfg, mesh, rules, params,
                          EngineConfig(max_slots=2, max_len=32))
        rids = [eng.submit(p, max_new_tokens=b)
                for p, b in zip(prompts, [8, 5])]
        steps = 0
        while eng.has_work():
            eng.step()
            eng.check_invariants()
            steps += 1
            if steps == preempt_at and eng.slots[0] is not None:
                eng.preempt(0)
        return [list(eng.completions[r].tokens) for r in rids], eng

    want, _ = run(preempt_at=0)
    got, eng = run(preempt_at=3)
    assert eng.counters["preemptions"] == 1
    assert eng.counters["resumed"] == 1
    assert eng.counters["replayed_tokens"] > 0
    assert got == want
    # the preempted request's completion is one stream (no re-emission of
    # replayed tokens)
    assert len(got[0]) == 8


def test_recurrent_rejects_paged_options(rec_setup):
    """Recurrent state has no seq axis: every paged-only option must fail
    loudly at engine construction."""
    cfg, mesh, rules, params = rec_setup
    with pytest.raises(ValueError, match="no seq axis"):
        ServeEngine(cfg, mesh, rules, params,
                    EngineConfig(max_slots=1, max_len=32, kv_layout="paged"))
    for bad in (dict(prefill_chunk=8), dict(prefix_cache=True),
                dict(admission="preempt")):
        with pytest.raises(ValueError, match="paged"):
            ServeEngine(cfg, mesh, rules, params,
                        EngineConfig(max_slots=1, max_len=32, **bad))


def test_recurrent_generate_wrapper_and_bucket_reuse(rec_setup):
    """generate() routes the ssm family through the engine now (it used to
    fall back to the static loop) and matches it token-for-token; repeat
    admissions in the same bucket build nothing new."""
    cfg, mesh, rules, params = rec_setup
    rng = np.random.default_rng(4)
    prompts = np.stack(_prompts(cfg, rng, [8, 8, 8]))
    a = generate(cfg, mesh, rules, params, prompts,
                 serve=ServeConfig(max_new_tokens=5))
    b = generate_static(cfg, mesh, rules, params, prompts,
                        serve=ServeConfig(max_new_tokens=5))
    np.testing.assert_array_equal(a, b)

    eng = ServeEngine(cfg, mesh, rules, params,
                      EngineConfig(max_slots=2, max_len=64))
    eng.run(_prompts(cfg, rng, [3, 9, 14]), max_new_tokens=2)
    builds = eng.stats["builds"]
    assert builds == 2                          # decode + prefill@16
    eng.run(_prompts(cfg, rng, [5, 12, 7, 2]), max_new_tokens=3)
    assert eng.stats["builds"] == builds        # steady state: no builds
