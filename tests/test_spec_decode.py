"""Speculative decoding: unit + property tests for the draft/verify path.

The end-to-end contract (greedy spec == sequential greedy, bitwise, on
arbitrary streams) lives in the cross-engine fuzzer
(``test_engine_fuzz.py::test_fuzz_spec_parity``).  This file pins the
pieces that make that contract hold:

* :class:`RecurrentCache` snapshot/rollback is a bitwise per-lane select
  across every family's ``recurrent_leaf_axes`` layout — a lane that
  advanced ``j <= k`` speculative steps and then rejected is bitwise the
  state it had before advancing (property test, both recurrent archs).
* KV truncate-on-reject conserves the block pool: rejected positions
  never leak blocks or refs, and shared prefix blocks are never written
  past the committed length (the per-step invariant sweep enforces both
  while a rejection-heavy draft hammers the rollback path).
* Cross-feature races: cancel and deadline expiry landing between verify
  rounds refund fully; preempt-during-verify requeues only committed
  tokens; a spec lane spills/restores through the host tier O(copy); a
  seeded fault schedule over a spec engine never raises and keeps "ok"
  requests bitwise.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.core.aot import AotCache
from repro.models import registry
from repro.serve import EngineConfig, FaultPlan, ServeEngine
from repro.serve.cache import RecurrentCache

from test_engine_fuzz import (
    MAX_LEN, MAX_SLOTS, MODES, SPEC_K, _draft_mix, _FakeClock, drive,
    drive_chaos, make_stream, spec_modes,
)

REC_ARCHS = ("xlstm-1.3b", "zamba2-1.2b")


@pytest.fixture(scope="module")
def setup():
    from repro.launch.mesh import single_device_mesh
    from repro.models.common import ShardRules

    mesh = single_device_mesh()
    rules = ShardRules.for_mesh(mesh)
    cfg = dataclasses.replace(
        get_smoke_config("smollm-360m"), compute_dtype="float32")
    params = registry.get_module(cfg).init(cfg, jax.random.PRNGKey(0))
    dparams = _draft_mix(cfg, params, 0.15)
    return cfg, mesh, rules, params, dparams, AotCache("spec")


# ---------------------------------------------------------------------------
# Config validation
# ---------------------------------------------------------------------------


def test_spec_config_validation(setup):
    cfg, mesh, rules, params, dparams, aot = setup
    base = dict(max_slots=MAX_SLOTS, max_len=MAX_LEN)
    with pytest.raises(ValueError, match="spec_k"):
        ServeEngine(cfg, mesh, rules, params,
                    EngineConfig(**base, spec_draft=cfg), aot=aot)
    with pytest.raises(ValueError, match="spec_draft"):
        ServeEngine(cfg, mesh, rules, params,
                    EngineConfig(**base, spec_k=3), aot=aot)
    with pytest.raises(ValueError, match="fused_sampling"):
        ServeEngine(cfg, mesh, rules, params,
                    EngineConfig(**base, spec_draft=cfg, spec_k=3,
                                 fused_sampling=False), aot=aot)
    with pytest.raises(ValueError, match="vocab"):
        bad = dataclasses.replace(cfg, vocab=cfg.vocab + 64)
        ServeEngine(cfg, mesh, rules, params,
                    EngineConfig(**base, spec_draft=bad, spec_k=3), aot=aot)
    with pytest.raises(ValueError, match="draft_params"):
        ServeEngine(cfg, mesh, rules, params, EngineConfig(**base),
                    aot=aot, draft_params=dparams)


# ---------------------------------------------------------------------------
# RecurrentCache snapshot/rollback: bitwise per-lane select (property)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module", params=REC_ARCHS)
def rec_cfg(request):
    return dataclasses.replace(
        get_smoke_config(request.param), compute_dtype="float32")


def _random_recurrent_leaves(cfg, rec, rng, slots=MAX_SLOTS, length=32):
    """Random arrays in each recurrent leaf's real shape/dtype."""
    sds = registry.get_module(cfg).make_cache_specs(cfg, slots, length)
    out = {}
    for name in rec.leaf_axes:
        sd = sds[name]
        if np.issubdtype(np.dtype(sd.dtype), np.integer):
            arr = rng.integers(0, 7, sd.shape).astype(sd.dtype)
        else:
            arr = rng.standard_normal(sd.shape).astype(sd.dtype)
        out[name] = jnp.asarray(arr)
    return out


@settings(max_examples=15, deadline=None)
@given(j=st.integers(min_value=0, max_value=SPEC_K + 1),
       seed=st.integers(min_value=0, max_value=10_000))
def test_snapshot_rollback_bitwise(rec_cfg, j, seed):
    """Snapshot, advance ``j <= k+1`` whole-state rewrites (a decode step
    rewrites the entire recurrent state), then roll back with a random
    keep mask: kept lanes are bitwise the advanced state, rolled-back
    lanes are bitwise the snapshot — for every leaf and every lane-axis
    layout the family declares.  ``j == 0`` pins the degenerate
    never-advanced case (rollback must still be an exact identity)."""
    rec = RecurrentCache(rec_cfg)
    assert rec, f"{rec_cfg.family} declares no recurrent leaves"
    rng = np.random.default_rng(seed)
    cache0 = _random_recurrent_leaves(rec_cfg, rec, rng)
    snap = rec.snapshot(cache0)
    cache = dict(cache0)
    for _ in range(j):
        cache = {
            n: c * np.asarray(1.25, c.dtype)
            + jnp.asarray(rng.standard_normal(c.shape).astype(c.dtype))
            if not np.issubdtype(np.dtype(c.dtype), np.integer)
            else c + 1
            for n, c in cache.items()
        }
    keep = rng.integers(0, 2, MAX_SLOTS).astype(bool)
    out = rec.rollback(cache, snap, jnp.asarray(keep))
    for name, axis in rec.leaf_axes.items():
        got = np.asarray(out[name])
        adv = np.asarray(cache[name])
        orig = np.asarray(cache0[name])
        for lane in range(MAX_SLOTS):
            ref = adv if keep[lane] else orig
            np.testing.assert_array_equal(
                np.take(got, lane, axis=axis),
                np.take(ref, lane, axis=axis),
                err_msg=f"{rec_cfg.family} leaf {name!r} lane {lane} "
                        f"(keep={bool(keep[lane])}, j={j})")


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_rollback_composes_with_freeze(rec_cfg, seed):
    """The verify program's per-step ladder composes rollback with the
    evict-time freeze: frozen (inactive) lanes stay exactly zero through
    a rollback, and rolled-back active lanes are untouched by a freeze
    that keeps them."""
    rec = RecurrentCache(rec_cfg)
    rng = np.random.default_rng(seed)
    cache = _random_recurrent_leaves(rec_cfg, rec, rng)
    active = rng.integers(0, 2, MAX_SLOTS).astype(bool)
    keep = rng.integers(0, 2, MAX_SLOTS).astype(bool)
    snap = rec.snapshot(cache)
    frozen = rec.freeze(cache, jnp.asarray(active))
    out = rec.rollback(frozen, snap, jnp.asarray(keep))
    inactive = [i for i in range(MAX_SLOTS) if not active[i] and keep[i]]
    assert rec.lanes_are_zero(out, inactive)
    for name, axis in rec.leaf_axes.items():
        for lane in range(MAX_SLOTS):
            if active[lane] and keep[lane]:
                np.testing.assert_array_equal(
                    np.take(np.asarray(out[name]), lane, axis=axis),
                    np.take(np.asarray(cache[name]), lane, axis=axis))


# ---------------------------------------------------------------------------
# KV truncate-on-reject: block-pool conservation under heavy rejection
# ---------------------------------------------------------------------------


def test_kv_truncate_on_reject_conserves_pool(setup):
    """A rejection-heavy draft (pure fresh init) forces the KV truncate
    path nearly every round on a paged + prefix-cached engine.  The
    per-step invariant sweep inside ``drive`` enforces the two
    conservation properties on every step: free + live + cached == pool
    capacity with exact refcounts, and any block mapped past a lane's
    committed length holds refcount 1 (a shared published block is never
    written by speculation).  Afterward the pool drains to zero in-use
    and the stream is still bitwise the sequential engine's."""
    cfg, mesh, rules, params, _, aot = setup
    junk = _draft_mix(cfg, params, 1.0)      # draft == fresh init
    stream = make_stream(np.random.default_rng(42), cfg.vocab)
    want, _ = drive(cfg, mesh, rules, params, aot, MODES["slotted"], stream)
    got, eng = drive(cfg, mesh, rules, params, aot,
                     spec_modes(cfg)["spec_prefix"], stream,
                     draft_params=junk)
    assert got == want
    assert eng.counters["spec_rejected"] > 0, "junk draft never rejected?"
    assert eng.alloc.in_use == 0
    assert eng.alloc.num_free + eng.alloc.num_cached == eng.alloc.capacity
    # a junk draft must not stall progress: every round still commits the
    # target's own sample, so throughput floors at sequential decode
    assert eng.stats["tokens_per_decode_dispatch"] >= 1.0


def test_spec_counters_and_stats(setup):
    """Counter accounting on a clean run: every non-replay verify round
    commits at least one token (the target's sample for the pending
    position), so committed/lane-rounds >= 1.0; the acceptance rate is a
    valid ratio; and a non-spec engine reports zeroed spec stats."""
    cfg, mesh, rules, params, dparams, aot = setup
    stream = make_stream(np.random.default_rng(43), cfg.vocab)
    _, eng = drive(cfg, mesh, rules, params, aot,
                   spec_modes(cfg)["spec_slotted"], stream,
                   draft_params=dparams)
    st_ = eng.stats
    assert eng.counters["spec_rounds"] > 0
    assert st_["tokens_per_decode_dispatch"] >= 1.0
    assert 0.0 <= st_["spec_acceptance_rate"] <= 1.0
    assert st_["spec_acceptance_rate"] == pytest.approx(
        eng.counters["spec_accepted"] / max(1, eng.counters["spec_drafted"]))
    # a non-spec engine never touches the spec counters and (like the
    # paged-only keys) doesn't report the spec stats at all
    _, plain = drive(cfg, mesh, rules, params, aot, MODES["slotted"], stream)
    assert plain.counters["spec_rounds"] == 0
    assert "tokens_per_decode_dispatch" not in plain.stats
    assert "spec_acceptance_rate" not in plain.stats


# ---------------------------------------------------------------------------
# Cross-feature races
# ---------------------------------------------------------------------------


def test_cancel_between_verify_rounds_refunds(setup):
    """Cancel landing between verify rounds: the lane's blocks and
    deficit refund fully, the cancelled stream is a prefix of the
    sequential stream, and the surviving request is untouched."""
    cfg, mesh, rules, params, dparams, aot = setup
    prompts = [np.arange(1, 10, dtype=np.int32),
               np.arange(3, 15, dtype=np.int32)]
    stream = [(0, p, 12) for p in prompts]
    want, _ = drive(cfg, mesh, rules, params, aot, MODES["slotted"], stream)
    eng = ServeEngine(cfg, mesh, rules, params,
                      spec_modes(cfg)["spec_prefix"], aot=aot,
                      draft_params=dparams)
    for i, p in enumerate(prompts):
        eng.submit(p, max_new_tokens=12, rid=i)
    eng.step()
    eng.step()                      # a couple of verify rounds committed
    emitted = len(eng.live[0].tokens)
    assert emitted >= 1
    assert eng.cancel(0)
    eng.check_invariants()
    guard = 0
    while eng.has_work():
        eng.step()
        eng.check_invariants()
        guard += 1
        assert guard < 100
    c0, c1 = eng.completions[0], eng.completions[1]
    assert c0.status == "cancelled"
    assert list(c0.tokens) == want[0][: len(c0.tokens)]
    assert len(c0.tokens) >= emitted
    assert c1.status == "ok" and list(c1.tokens) == want[1]
    assert eng.alloc.in_use == 0


def test_deadline_expiry_mid_speculation(setup):
    """A TTL expiring while a lane is mid-speculation: the emitted
    (committed) prefix survives on the timed-out completion, blocks
    refund, and nothing past the committed stream ever leaks out —
    verify-round overshoot is never visible."""
    cfg, mesh, rules, params, dparams, aot = setup
    prompt = np.arange(2, 11, dtype=np.int32)
    want, _ = drive(cfg, mesh, rules, params, aot, MODES["slotted"],
                    [(0, prompt, 30)])
    clock = _FakeClock()
    eng = ServeEngine(cfg, mesh, rules, params,
                      spec_modes(cfg)["spec_slotted"], aot=aot,
                      draft_params=dparams, clock=clock)
    eng.submit(prompt, max_new_tokens=30, rid=0, deadline_s=2.5)
    guard = 0
    while eng.has_work():
        eng.step()
        eng.check_invariants()
        clock.t += 1.0
        guard += 1
        assert guard < 100
    c = eng.completions[0]
    assert c.status == "timeout"
    assert 0 < len(c.tokens) < 30
    assert list(c.tokens) == want[0][: len(c.tokens)]


def test_preempt_during_speculation_requeues_committed_only(setup):
    """Host preempt with a lane mid-speculation: the requeued resume
    carries exactly the committed tokens (replay count == committed
    emissions at preempt time), and the finished stream is bitwise the
    sequential engine's — an overshoot position surviving the preempt
    would diverge here."""
    cfg, mesh, rules, params, dparams, aot = setup
    prompt = np.arange(5, 14, dtype=np.int32)
    stream = [(0, prompt, 10)]
    want, _ = drive(cfg, mesh, rules, params, aot, MODES["slotted"], stream)
    eng = ServeEngine(cfg, mesh, rules, params,
                      spec_modes(cfg)["spec_slotted"], aot=aot,
                      draft_params=dparams)
    eng.submit(prompt, max_new_tokens=10, rid=0)
    eng.step()
    committed = len(eng.live[0].tokens)
    assert 1 <= committed <= SPEC_K + 1
    eng.preempt(0)
    eng.check_invariants()
    guard = 0
    while eng.has_work():
        eng.step()
        eng.check_invariants()
        guard += 1
        assert guard < 100
    assert eng.counters["preemptions"] == 1
    assert eng.counters["replayed_tokens"] == committed
    c = eng.completions[0]
    assert c.status == "ok" and list(c.tokens) == want[0]


def test_spec_lane_spills_and_restores_o_copy(setup):
    """A spec lane through the host tier: preempt spills the lane's
    blocks to host RAM, the resume restores them O(copy) — zero replayed
    decode steps, zero re-prefilled prompt tokens — and the draft cache
    is rebuilt from the committed history so speculation continues
    bitwise."""
    cfg, mesh, rules, params, dparams, aot = setup
    prompt = np.arange(7, 19, dtype=np.int32)
    stream = [(0, prompt, 10)]
    want, _ = drive(cfg, mesh, rules, params, aot, MODES["slotted"], stream)
    eng = ServeEngine(cfg, mesh, rules, params,
                      spec_modes(cfg)["spec_tiered"], aot=aot,
                      draft_params=dparams)
    eng.submit(prompt, max_new_tokens=10, rid=0)
    eng.step()
    assert len(eng.live[0].tokens) >= 1
    eng.preempt(0)
    eng.check_invariants()
    guard = 0
    while eng.has_work():
        eng.step()
        eng.check_invariants()
        guard += 1
        assert guard < 100
    assert eng.counters["spills"] >= 1
    assert eng.counters["restores"] >= 1
    assert eng.counters["replayed_tokens"] == 0, (
        "tier restore replayed decode steps — resume must be O(copy)")
    eng.tier.check()
    assert eng.tier.spilled_lanes == 0
    c = eng.completions[0]
    assert c.status == "ok" and list(c.tokens) == want[0]


def test_spec_chaos_never_raises(setup):
    """A seeded fault schedule (corrupted verify fetches, failed prefill
    and alloc, lost sched pushes) over a preempting spec engine: step()
    never raises, invariants hold every step, and every request that
    finishes "ok" is bitwise the fault-free sequential stream."""
    cfg, mesh, rules, params, dparams, aot = setup
    rates = {"decode_logits": 0.1, "prefill": 0.1, "alloc": 0.05,
             "sched_push": 0.1}
    detected = 0
    for seed in range(3):
        rng = np.random.default_rng(8800 + seed)
        stream = make_stream(rng, cfg.vocab)
        want, _ = drive(cfg, mesh, rules, params, aot, MODES["slotted"],
                        stream)
        eng = drive_chaos(cfg, mesh, rules, params, aot,
                          spec_modes(cfg)["spec_preempt"], stream,
                          FaultPlan(seed, rates), deadline_every=4,
                          cancel_ticks={int(rng.integers(1, 20))},
                          draft_params=dparams)
        for rid in range(len(stream)):
            c = eng.completions[rid]
            assert c.status in ("ok", "timeout", "cancelled", "failed")
            got = list(c.tokens)
            if c.status == "ok":
                assert got == want[rid], (
                    f"seed={seed} rid={rid}: ok spec request diverged "
                    f"under faults\n  want={want[rid]}\n  got ={got}")
            else:
                assert got == want[rid][: len(got)]
        assert eng.alloc.in_use == 0
        detected += eng.stats["faults_detected"]
    assert detected > 0, "no fault ever detected (vacuous chaos run)"


def test_spec_prebuild_keeps_builds_flat(setup):
    """After prebuild, a spec drive — admissions, verify rounds, draft
    rebuilds, preempts — dispatches purely from the AOT cache."""
    cfg, mesh, rules, params, dparams, aot = setup
    ec = spec_modes(cfg)["spec_preempt"]
    ServeEngine(cfg, mesh, rules, params, ec, aot=aot,
                draft_params=dparams).prebuild()
    builds0 = aot.stats["builds"]
    stream = make_stream(np.random.default_rng(44), cfg.vocab)
    drive(cfg, mesh, rules, params, aot, ec, stream, draft_params=dparams)
    assert aot.stats["builds"] == builds0, (
        "spec decode built executables after prebuild")
