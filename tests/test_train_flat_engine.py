"""The flat-gradient bucket engine in train/step.py: mode gating, and a
structural proof that faithful mode actually lowers to the flat buffer +
bucketed reduce + fused flat-Adam (not just a loss-value check).

Multi-device numerical parity (flat vs legacy vs ZeRO on 8 workers) runs
in a subprocess — test_core_multidevice.py::test_flat_engine_parity.
"""
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.train.step as step_mod
from repro.configs import get_smoke_config
from repro.launch.mesh import _mk, single_device_mesh
from repro.models import registry
from repro.models.common import ShardRules
from repro.optim import OptConfig
from repro.train.step import (
    TrainSettings, build_train_step, flat_engine_mode, opt_state_template,
)

CFG = get_smoke_config("smollm-360m")


def _abstract_args(cfg, mesh, rules, opt, settings, B=4, S=16):
    params_sds = registry.abstract_params(cfg)
    init_fn, _ = opt_state_template(cfg, mesh, rules, opt, settings)
    opt_sds = jax.eval_shape(init_fn, params_sds)
    batch_sds = {"tokens": jax.ShapeDtypeStruct((B, S + 1), jnp.int32)}
    return params_sds, opt_sds, batch_sds


# ---------------------------------------------------------------------------
# Mode gating
# ---------------------------------------------------------------------------


def test_mode_gating():
    mesh_dp = _mk((1, 1), ("data", "model"))
    adam = OptConfig(kind="adam")
    # faithful + pure DP + adam -> flat engine
    assert flat_engine_mode(CFG, mesh_dp, adam, TrainSettings(faithful=True)) \
        == "faithful"
    # default non-faithful -> GSPMD
    assert flat_engine_mode(CFG, mesh_dp, adam, TrainSettings()) is None
    # explicit ZeRO opt-in
    assert flat_engine_mode(CFG, mesh_dp, adam, TrainSettings(flat_engine="zero")) \
        == "zero"
    # off wins
    assert flat_engine_mode(
        CFG, mesh_dp, adam, TrainSettings(faithful=True, flat_engine="off")) is None
    # non-adam rules fall back
    assert flat_engine_mode(
        CFG, mesh_dp, OptConfig(kind="sgd"), TrainSettings(faithful=True)) is None
    # live model axis falls back
    mesh_tp = _mk((1, 2), ("data", "model")) if jax.device_count() >= 2 else None
    if mesh_tp is not None:
        assert flat_engine_mode(
            CFG, mesh_tp, adam, TrainSettings(faithful=True)) is None
    # MoE (internal shard_map) falls back
    moe_cfg = get_smoke_config("qwen3-moe-30b-a3b")
    assert flat_engine_mode(
        moe_cfg, mesh_dp, adam, TrainSettings(faithful=True)) is None
    # bad value rejected
    with pytest.raises(ValueError):
        flat_engine_mode(CFG, mesh_dp, adam, TrainSettings(flat_engine="bogus"))
    # an EXPLICIT zero request that cannot engage raises (never silently
    # hands back unsharded optimizer state)
    with pytest.raises(ValueError, match="zero.*unavailable|adam"):
        flat_engine_mode(CFG, mesh_dp, OptConfig(kind="sgd"),
                         TrainSettings(flat_engine="zero"))
    with pytest.raises(ValueError, match="conflicts with faithful"):
        flat_engine_mode(CFG, mesh_dp, adam,
                         TrainSettings(faithful=True, flat_engine="zero"))
    with pytest.raises(ValueError, match="MoE"):
        flat_engine_mode(moe_cfg, mesh_dp, adam, TrainSettings(flat_engine="zero"))
    # multi-data-axis mesh (pod x data) can't zero either
    mesh_pod = _mk((1, 1, 1), ("pod", "data", "model"))
    with pytest.raises(ValueError, match="one data axis"):
        flat_engine_mode(CFG, mesh_pod, adam, TrainSettings(flat_engine="zero"))


def test_zero_state_is_flat_and_scattered():
    mesh = _mk((1, 1), ("data", "model"))  # single data axis: zero engages
    rules = ShardRules.for_mesh(mesh)
    opt = OptConfig(kind="adam", bucket_mb=0.05)
    settings = TrainSettings(flat_engine="zero")
    assert flat_engine_mode(CFG, mesh, opt, settings) == "zero"
    init_fn, pspecs = opt_state_template(CFG, mesh, rules, opt, settings)
    sds = jax.eval_shape(init_fn, registry.abstract_params(CFG))
    assert sds["m"].ndim == 1 and sds["v"].ndim == 1
    buckets = step_mod.buckets_for(CFG, mesh, opt, n_shards=1)
    assert sds["m"].shape[0] >= buckets.total


# ---------------------------------------------------------------------------
# Update-path inspection (acceptance criterion: not just the loss value)
# ---------------------------------------------------------------------------


def test_faithful_step_routes_through_bucketed_flat_adam(monkeypatch):
    """Spy on the engine entry points while the faithful step traces."""
    mesh = single_device_mesh()
    rules = ShardRules.for_mesh(mesh, faithful=True)
    opt = OptConfig(kind="adam", lr=1e-3, bucket_mb=0.05)
    settings = TrainSettings(faithful=True)

    seen = {}
    real_ar = step_mod.bucketed_all_reduce
    real_fa = step_mod.flat_adam_apply

    def spy_ar(buf, buckets, axes, op="mean"):
        seen["all_reduce"] = (buckets.num_buckets, op, int(buf.shape[0]))
        return real_ar(buf, buckets, axes, op=op)

    def spy_fa(p, g, m, v, step, **kw):
        seen["flat_adam"] = (int(p.shape[0]), p.ndim)
        return real_fa(p, g, m, v, step, **kw)

    monkeypatch.setattr(step_mod, "bucketed_all_reduce", spy_ar)
    monkeypatch.setattr(step_mod, "flat_adam_apply", spy_fa)

    step = build_train_step(CFG, mesh, rules, opt, settings)
    assert step._flat_engine == "faithful"
    assert step._flat_buckets.num_buckets > 1
    args = _abstract_args(CFG, mesh, rules, opt, settings)
    jax.eval_shape(step, *args)  # trace only

    nb, op, flat_len = seen["all_reduce"]
    assert nb == step._flat_buckets.num_buckets and op == "mean"
    assert flat_len == step._flat_layout.total
    # the fused update ran over the ONE flat 1-D buffer, not per-parameter
    assert seen["flat_adam"] == (step._flat_layout.total, 1)


def test_faithful_step_psum_count_tracks_bucket_count():
    """Structural check in the traced program: each extra bucket adds
    exactly one more psum collective."""
    mesh = single_device_mesh()
    rules = ShardRules.for_mesh(mesh, faithful=True)
    settings = TrainSettings(faithful=True)

    def psum_count(bucket_mb):
        opt = OptConfig(kind="adam", lr=1e-3, bucket_mb=bucket_mb)
        step = build_train_step(CFG, mesh, rules, opt, settings)
        args = _abstract_args(CFG, mesh, rules, opt, settings)
        jaxpr = jax.make_jaxpr(step)(*args)
        return str(jaxpr).count("psum["), step._flat_buckets.num_buckets

    small, nb_small = psum_count(0.05)
    mono, nb_mono = psum_count(1 << 12)
    assert nb_mono == 1 and nb_small > 1
    assert small - mono == nb_small - nb_mono


def test_faithful_flat_step_runs_and_matches_legacy_numerics(key):
    mesh = single_device_mesh()
    rules = ShardRules.for_mesh(mesh, faithful=True)
    opt = OptConfig(kind="adam", lr=1e-3, bucket_mb=0.05)
    mod = registry.get_module(CFG)
    params = mod.init(CFG, key)
    batch = {"tokens": jax.random.randint(key, (4, 17), 0, CFG.vocab)}

    def run(settings):
        step = build_train_step(CFG, mesh, rules, opt, settings)
        init_fn, _ = opt_state_template(CFG, mesh, rules, opt, settings)
        p, o, m = jax.jit(step)(params, init_fn(params), batch)
        return p, m

    p_flat, m_flat = run(TrainSettings(faithful=True))
    p_leg, m_leg = run(TrainSettings(faithful=True, flat_engine="off"))
    assert np.isfinite(float(m_flat["loss"]))
    np.testing.assert_allclose(
        float(m_flat["loss"]), float(m_leg["loss"]), rtol=1e-5)
    np.testing.assert_allclose(
        float(m_flat["grad_norm"]), float(m_leg["grad_norm"]), rtol=1e-4)
    # single worker: same math up to reduction order; updates must be tiny-close
    for a, b in zip(jax.tree.leaves(p_flat), jax.tree.leaves(p_leg)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=5e-5)
    changed = any(
        bool(np.any(np.asarray(a) != np.asarray(b)))
        for a, b in zip(jax.tree.leaves(p_flat), jax.tree.leaves(params)))
    assert changed
